//! Engine-layer integration: the plan cache must be invisible in the
//! numbers (bit-identical to uncached runs) and visible in the work
//! (one plan/DDM computation per (design, network), counted by the
//! hit/miss counters), including under the parallel sweep runner.

use pimflow::cfg::presets;
use pimflow::explore::{self, BATCHES};
use pimflow::nn::resnet;
use pimflow::sim::{find, Design, Engine, System};

fn engine() -> Engine {
    Engine::compact(presets::lpddr5())
}

#[test]
fn cached_and_uncached_reports_are_bit_identical() {
    let net = resnet::resnet34(100);
    let eng = engine();
    // Warm the cache, then run the same point again plus an uncached System.
    let first = eng.system_report(Design::CompactDdm, &net, 256).unwrap();
    let cached = eng.system_report(Design::CompactDdm, &net, 256).unwrap();
    let uncached = System::new(presets::compact_rram_41mm2(), presets::lpddr5())
        .try_run(&net, 256)
        .unwrap();
    assert!(eng.cache_stats().hits >= 1, "second run must hit the cache");
    for other in [&cached, &uncached] {
        assert_eq!(first.throughput_fps.to_bits(), other.throughput_fps.to_bits());
        assert_eq!(first.per_ifm_ns.to_bits(), other.per_ifm_ns.to_bits());
        assert_eq!(first.tops_per_watt.to_bits(), other.tops_per_watt.to_bits());
        assert_eq!(first.gops_per_mm2.to_bits(), other.gops_per_mm2.to_bits());
        assert_eq!(first.area_mm2.to_bits(), other.area_mm2.to_bits());
        assert_eq!(
            first.compute_fraction.to_bits(),
            other.compute_fraction.to_bits()
        );
        assert_eq!(first.num_parts, other.num_parts);
        assert_eq!(
            first.energy.total_j().to_bits(),
            other.energy.total_j().to_bits()
        );
        assert_eq!(
            first.pipeline.makespan_ns.to_bits(),
            other.pipeline.makespan_ns.to_bits()
        );
    }
}

#[test]
fn fig6_sweep_plans_once_per_design_per_network() {
    let net = resnet::resnet34(100);
    let eng = engine();
    let pts = explore::fig6_sweep(&eng, &net, &BATCHES).unwrap();
    assert_eq!(pts.len(), Design::FIG6.len() * BATCHES.len());
    let stats = eng.cache_stats();
    // GPU is analytic; the four simulated designs plan exactly once each.
    assert_eq!(stats.misses, 4, "{stats:?}");
    assert_eq!(stats.hits, 4 * BATCHES.len() as u64, "{stats:?}");

    // A second sweep over the same grid is all hits.
    let _ = explore::fig6_sweep(&eng, &net, &BATCHES).unwrap();
    let stats2 = eng.cache_stats();
    assert_eq!(stats2.misses, 4, "no re-planning on the second sweep");
    assert!(stats2.hits > stats.hits);
}

#[test]
fn fig8_sweep_plans_once_per_design_per_network() {
    let eng = engine();
    let family = resnet::paper_family(100);
    let pts = explore::fig8_sweep(&eng, &family, 64).unwrap();
    assert_eq!(pts.len(), Design::FIG8.len() * family.len());
    let stats = eng.cache_stats();
    assert_eq!(
        stats.misses,
        (Design::FIG8.len() * family.len()) as u64,
        "one plan per (design, network): {stats:?}"
    );
    // A different batch on the same engine reuses every plan.
    let _ = explore::fig8_sweep(&eng, &family, 16).unwrap();
    assert_eq!(eng.cache_stats().misses, stats.misses);
}

#[test]
fn parallel_sweep_equals_sequential_runs_bitwise() {
    let net = resnet::resnet18(100);
    let eng = engine();
    let pts = eng.sweep(&net, &Design::FIG6, &[1, 16, 256]).unwrap();
    let fresh = engine();
    for p in &pts {
        let serial = fresh.run(p.design, &net, p.batch).unwrap();
        assert_eq!(
            p.throughput_fps.to_bits(),
            serial.throughput_fps.to_bits(),
            "{:?} batch {}",
            p.design,
            p.batch
        );
        assert_eq!(p.tops_per_watt.to_bits(), serial.tops_per_watt.to_bits());
    }
    // Grid order: design-major, batch-minor.
    assert_eq!(find(&pts, Design::Gpu, 1).unwrap().batch, pts[0].batch);
    assert_eq!(pts[0].design, Design::Gpu);
}

#[test]
fn engine_distinguishes_dram_generations() {
    let net = resnet::resnet18(100);
    let e5 = Engine::compact(presets::lpddr5());
    let e3 = Engine::compact(presets::dram(pimflow::cfg::DramKind::Lpddr3));
    let r5 = e5.system_report(Design::CompactDdm, &net, 64).unwrap();
    let r3 = e3.system_report(Design::CompactDdm, &net, 64).unwrap();
    assert!(r3.energy.dram_j > r5.energy.dram_j);
}

#[test]
fn plan_accounting_is_insertion_order_independent() {
    let r18 = resnet::resnet18(100);
    let r34 = resnet::resnet34(100);
    let a = engine();
    a.warm(Design::CompactDdm, &r34).unwrap();
    a.warm(Design::CompactDdm, &r18).unwrap();
    a.warm(Design::CompactNoDdm, &r18).unwrap();
    let b = engine();
    b.warm(Design::CompactNoDdm, &r18).unwrap();
    b.warm(Design::CompactDdm, &r18).unwrap();
    b.warm(Design::CompactDdm, &r34).unwrap();

    assert_eq!(a.planned_networks(), vec!["resnet18", "resnet34"]);
    assert_eq!(a.planned_networks(), b.planned_networks());
    assert_eq!(a.plan_manifest(), b.plan_manifest());
    assert_eq!(a.plans_for("resnet18"), 2);
    assert_eq!(a.plans_for("resnet34"), 1);

    // The manifest is sorted and holds exactly the content hashes the
    // store/shard layer addresses these plans by.
    let manifest = a.plan_manifest();
    assert!(manifest.windows(2).all(|w| w[0] <= w[1]), "sorted: {manifest:?}");
    let mut expect = vec![
        ("resnet18".to_string(), a.plan_hash(Design::CompactDdm, &r18).unwrap()),
        ("resnet18".to_string(), a.plan_hash(Design::CompactNoDdm, &r18).unwrap()),
        ("resnet34".to_string(), a.plan_hash(Design::CompactDdm, &r34).unwrap()),
    ];
    expect.sort();
    assert_eq!(manifest, expect);
}

#[test]
fn global_lock_cache_sweep_is_bitwise_identical_to_striped() {
    let net = resnet::resnet34(100);
    let striped = engine();
    let global = engine().with_global_lock_cache();
    let a = striped.sweep(&net, &Design::FIG6, &[1, 16, 256]).unwrap();
    let b = global.sweep(&net, &Design::FIG6, &[1, 16, 256]).unwrap();
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.throughput_fps.to_bits(), y.throughput_fps.to_bits());
        assert_eq!(x.tops_per_watt.to_bits(), y.tops_per_watt.to_bits());
        assert_eq!(x.gops_per_mm2.to_bits(), y.gops_per_mm2.to_bits());
        assert_eq!(x.num_parts, y.num_parts);
    }
    assert_eq!(striped.cache_stats(), global.cache_stats());
    assert_eq!(striped.plan_manifest(), global.plan_manifest());
}
