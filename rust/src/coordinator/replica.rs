//! Fleet-level weight replication: tracking which workers hold which
//! network's weights ([`ReplicaSet`]) and deciding when to spend worker
//! capacity widening a hot network's serving lane ([`ReplicationPolicy`]
//! + [`ReplicaController`]).
//!
//! The paper's core lever is weight reuse: off-chip weight traffic
//! dominates compact-PIM serving cost, and DDM already prices *intra-chip*
//! duplication (spending idle tiles to widen a layer's lane). This module
//! is the fleet-level analogue: a network resident on several workers has
//! a wider serving lane — `NetworkAffinity` placement routes to the
//! least-loaded member of its replica set — at the cost of the capacity
//! those workers could have lent to other networks.
//!
//! Three policies:
//!
//! * [`ReplicationPolicy::None`] — residency changes only through batch
//!   execution (a worker holds whatever it last ran). This is exactly the
//!   pre-replication model and replays bitwise-identically to it under
//!   every placement policy (pinned in `tests/replica_sim.rs`).
//! * [`ReplicationPolicy::Static`] — pinned replica targets per network.
//!   The controller pre-warms weights until each network holds its target
//!   number of replicas, stealing only workers that are empty or hold a
//!   *surplus* network (one above its own target); it never drains.
//! * [`ReplicationPolicy::Adaptive`] — a controller that watches a
//!   sliding window of per-network arrival times and realized reload
//!   costs. When a network's windowed reload spend reaches the amortized
//!   cost of one pre-warm (`headroom ×` its weight-streaming time), the
//!   controller grows its replica target and pre-warms the weights onto
//!   an idle worker — converting the *next* blocking reload into an
//!   off-critical-path stream. Networks with no arrivals in the window
//!   are drained, freeing their workers as pre-warm targets.
//!
//! Pre-warm pricing: streaming `net.weight_bytes()` over the DRAM channel
//! — the same `switch_s` a blocking reload pays — charged to the chosen
//! worker's `busy_until` (appended after whatever it already committed
//! to). A pre-warm never touches a worker with an open batch, so every
//! already-issued admission quote stays an upper bound and the
//! accepted-never-misses-SLO invariant survives replication unchanged.
//! Replication copies weights, never plans: the controller only ever uses
//! the per-network `switch_s` computed at server build, so K networks
//! still cost exactly K engine plans at any replica count (pinned in
//! `tests/replica_sim.rs` and `benches/hotpath.rs`).

use std::collections::VecDeque;

use super::placement::least_loaded;
use super::vworker::VWorker;

/// Which workers currently hold each network's weights — the fleet-level
/// residency index, maintained from worker load/evict events (batch
/// executions, pre-warms, drains). Invariant: `holders` is the exact
/// inverse of `resident`, with each holder list sorted by worker id
/// (property-checked against the event fold in `tests/replica_props.rs`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReplicaSet {
    /// `holders[net]` — sorted ids of workers whose resident network is `net`.
    holders: Vec<Vec<usize>>,
    /// `resident[worker]` — the network the worker holds, if any.
    resident: Vec<Option<usize>>,
}

impl ReplicaSet {
    /// Empty residency: no worker holds anything.
    pub fn new(num_nets: usize, num_workers: usize) -> Self {
        ReplicaSet {
            holders: vec![Vec::new(); num_nets],
            resident: vec![None; num_workers],
        }
    }

    /// Worker `w` now holds `net` (evicting whatever it held before).
    pub fn on_load(&mut self, w: usize, net: usize) {
        if self.resident[w] == Some(net) {
            return;
        }
        if let Some(old) = self.resident[w] {
            self.holders[old].retain(|&x| x != w);
        }
        let pos = self.holders[net].partition_point(|&x| x < w);
        self.holders[net].insert(pos, w);
        self.resident[w] = Some(net);
    }

    /// Worker `w` dropped its resident weights (a drain).
    pub fn on_evict(&mut self, w: usize) {
        if let Some(old) = self.resident[w].take() {
            self.holders[old].retain(|&x| x != w);
        }
    }

    /// Sorted worker ids currently holding `net`'s weights.
    pub fn holders(&self, net: usize) -> &[usize] {
        &self.holders[net]
    }

    /// Replica count of `net`.
    pub fn count(&self, net: usize) -> usize {
        self.holders[net].len()
    }

    /// The network worker `w` holds, if any.
    pub fn resident(&self, w: usize) -> Option<usize> {
        self.resident[w]
    }

    /// Whether worker `w` holds `net`'s weights.
    pub fn is_holder(&self, w: usize, net: usize) -> bool {
        self.resident[w] == Some(net)
    }

    pub fn num_workers(&self) -> usize {
        self.resident.len()
    }

    pub fn num_nets(&self) -> usize {
        self.holders.len()
    }

    /// Final holder lists, per network (for reports).
    pub fn snapshot(&self) -> Vec<Vec<usize>> {
        self.holders.clone()
    }

    /// Rebuild residency purely from a load/evict event log — the
    /// conservation check: a fold over the events must reproduce the
    /// live set exactly.
    pub fn fold(num_nets: usize, num_workers: usize, events: &[ResidencyEvent]) -> ReplicaSet {
        let mut rs = ReplicaSet::new(num_nets, num_workers);
        for ev in events {
            match ev.change {
                ResidencyChange::Load => rs.on_load(ev.worker, ev.net),
                ResidencyChange::Evict => {
                    debug_assert_eq!(rs.resident(ev.worker), Some(ev.net));
                    rs.on_evict(ev.worker);
                }
            }
        }
        rs
    }
}

/// Residency event direction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResidencyChange {
    /// `worker` became a holder of `net`.
    Load,
    /// `worker` stopped holding `net`.
    Evict,
}

impl ResidencyChange {
    /// Stable lowercase name (timeline event names, CSV cells).
    pub fn label(&self) -> &'static str {
        match self {
            ResidencyChange::Load => "load",
            ResidencyChange::Evict => "evict",
        }
    }
}

/// Why a residency event happened.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResidencyCause {
    /// A batch executed on the worker (the load side charges the batch a
    /// blocking weight reload).
    Batch,
    /// The replica controller streamed the weights ahead of demand.
    Prewarm,
    /// The replica controller dropped a cold network's weights.
    Drain,
    /// A fault-plan crash destroyed the worker's resident weights
    /// (see `coordinator::chaos`). Always an evict; the repair shows up
    /// as a later `Batch` or `Prewarm` load somewhere in the fleet.
    Crash,
}

impl ResidencyCause {
    /// Stable lowercase name (timeline event args, CSV cells).
    pub fn label(&self) -> &'static str {
        match self {
            ResidencyCause::Batch => "batch",
            ResidencyCause::Prewarm => "prewarm",
            ResidencyCause::Drain => "drain",
            ResidencyCause::Crash => "crash",
        }
    }
}

/// One residency change, as logged by the serving simulator. The full log
/// folds back into the live [`ReplicaSet`] (`tests/replica_props.rs`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ResidencyEvent {
    /// Virtual time of the change, seconds.
    pub t_s: f64,
    pub worker: usize,
    pub net: usize,
    pub change: ResidencyChange,
    pub cause: ResidencyCause,
}

/// Tuning knobs for [`ReplicationPolicy::Adaptive`].
///
/// Two thresholds separate the controller's two moves:
///
/// * **repair** — a network with *zero* replicas whose windowed reload
///   spend covers `headroom ×` one pre-warm gets its residency restored
///   (it paid for weights it then lost; re-streaming them on an idle
///   worker is already amortized);
/// * **growth** — a network that keeps paying reloads *despite holding a
///   replica* (spend ≥ `growth_factor × headroom ×` one pre-warm) has
///   its lane contested, and widens to one more worker.
///
/// The asymmetry keeps cold networks from squatting on multi-replica
/// lanes: one reload funds at most one restored replica, while widening
/// demands sustained pain.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdaptiveConfig {
    /// Sliding-window length, virtual seconds, over which per-network
    /// arrivals and reload costs are watched.
    pub window_s: f64,
    /// Repair threshold: restore a lost residency once windowed reload
    /// spend reaches `headroom ×` one pre-warm of the network's weights.
    pub headroom: f64,
    /// Growth threshold multiplier on top of `headroom` for adding a
    /// replica to an already-resident network.
    pub growth_factor: f64,
    /// Replica-count ceiling per network (0 = the fleet size).
    pub max_replicas: usize,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        AdaptiveConfig {
            window_s: 0.25,
            headroom: 1.0,
            growth_factor: 3.0,
            max_replicas: 0,
        }
    }
}

/// How the fleet spends worker capacity on weight residency.
#[derive(Debug, Clone, PartialEq)]
pub enum ReplicationPolicy {
    /// No controller: residency changes only through batch execution —
    /// the pre-replication model, bitwise-preserved.
    None,
    /// Pinned replica targets: `targets` maps zoo network names to
    /// replica counts (the wildcard name `*` applies to every network;
    /// explicit names override it). Best effort: the controller never
    /// steals a worker from a network at or below its own target.
    Static { targets: Vec<(String, usize)> },
    /// Demand-driven targets from a sliding arrival/reload-cost window.
    Adaptive(AdaptiveConfig),
}

impl ReplicationPolicy {
    /// Stable label for tables/CSV.
    pub fn label(&self) -> &'static str {
        match self {
            ReplicationPolicy::None => "none",
            ReplicationPolicy::Static { .. } => "static",
            ReplicationPolicy::Adaptive(_) => "adaptive",
        }
    }

    /// Parse a CLI spec: `none`, `adaptive`, `adaptive:<window_ms>`,
    /// `static:<count>` (every network), or
    /// `static:<name>=<count>[,<name>=<count>...]`.
    pub fn parse(spec: &str) -> anyhow::Result<ReplicationPolicy> {
        match spec.split_once(':') {
            None if spec == "none" => Ok(ReplicationPolicy::None),
            None if spec == "adaptive" => {
                Ok(ReplicationPolicy::Adaptive(AdaptiveConfig::default()))
            }
            Some(("adaptive", ms)) => {
                let window_ms: f64 = ms
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad adaptive window `{ms}` (milliseconds)"))?;
                anyhow::ensure!(
                    window_ms.is_finite() && window_ms > 0.0,
                    "adaptive window must be positive and finite, got {window_ms}"
                );
                Ok(ReplicationPolicy::Adaptive(AdaptiveConfig {
                    window_s: window_ms * 1e-3,
                    ..AdaptiveConfig::default()
                }))
            }
            Some(("static", rest)) if !rest.is_empty() => {
                if let Ok(count) = rest.parse::<usize>() {
                    return Ok(ReplicationPolicy::Static {
                        targets: vec![("*".to_string(), count)],
                    });
                }
                let targets = rest
                    .split(',')
                    .map(|kv| {
                        let (name, count) = kv.split_once('=').ok_or_else(|| {
                            anyhow::anyhow!(
                                "static spec is static:<count> or static:<name>=<count>,..., got `{kv}`"
                            )
                        })?;
                        let count: usize = count
                            .parse()
                            .map_err(|_| anyhow::anyhow!("bad replica count `{count}`"))?;
                        Ok((name.trim().to_string(), count))
                    })
                    .collect::<anyhow::Result<Vec<_>>>()?;
                Ok(ReplicationPolicy::Static { targets })
            }
            _ => anyhow::bail!(
                "unknown replication spec `{spec}` (expected none, static:<spec>, adaptive)"
            ),
        }
    }
}

/// A planned residency change the serving simulator should apply.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicaAction {
    /// Stream `net`'s weights onto `worker` (which must have no open
    /// batch), charging the stream to its `busy_until`.
    Prewarm { worker: usize, net: usize },
    /// Drop `net`'s weights from `worker` (free: residency bookkeeping
    /// only).
    Drain { worker: usize, net: usize },
}

enum Mode {
    Off,
    /// Resolved per-network replica targets.
    Static(Vec<usize>),
    Adaptive(AdaptiveConfig),
}

/// The replication decision-maker. Owns the sliding windows and targets;
/// reads fleet state (`&[VWorker]`, `&ReplicaSet`) and plans one
/// [`ReplicaAction`] at a time — the simulator applies it and re-plans
/// until the controller is satisfied, so every plan sees the residency
/// its previous action produced. Everything is driven by virtual-time
/// arrival events: same trace, same decisions, bit for bit.
pub struct ReplicaController {
    mode: Mode,
    /// Per-network pre-warm cost, seconds (the reload `switch_s`).
    prewarm_s: Vec<f64>,
    /// Current replica targets (observability; `None` mode keeps zeros).
    targets: Vec<usize>,
    /// Whether each network has ever arrived (drains only apply to
    /// networks that were live once).
    seen: Vec<bool>,
    /// Arrival times within the window, per network.
    arrivals: Vec<VecDeque<f64>>,
    /// `(time, cost_s)` of blocking reloads within the window, per network.
    reloads: Vec<VecDeque<(f64, f64)>>,
}

impl ReplicaController {
    /// Build a controller for `num_workers` workers over networks named
    /// `names`, with `prewarm_s[net]` the cost of streaming each
    /// network's weights. Static targets resolve against `names` (unknown
    /// names are errors) and clamp to the fleet size.
    pub fn new(
        policy: &ReplicationPolicy,
        names: &[&str],
        prewarm_s: &[f64],
        num_workers: usize,
    ) -> anyhow::Result<Self> {
        debug_assert_eq!(names.len(), prewarm_s.len());
        let n = names.len();
        let mode = match policy {
            ReplicationPolicy::None => Mode::Off,
            ReplicationPolicy::Static { targets } => {
                let mut resolved = vec![0usize; n];
                // Wildcard first, so explicit names override it.
                for (name, count) in targets.iter().filter(|(name, _)| name == "*") {
                    debug_assert_eq!(name, "*");
                    resolved.iter_mut().for_each(|t| *t = *count);
                }
                for (name, count) in targets.iter().filter(|(name, _)| name != "*") {
                    let idx = names.iter().position(|x| x == name).ok_or_else(|| {
                        anyhow::anyhow!(
                            "static replication names unknown network `{name}` \
                             (serving: {})",
                            names.join(", ")
                        )
                    })?;
                    resolved[idx] = *count;
                }
                resolved.iter_mut().for_each(|t| *t = (*t).min(num_workers));
                Mode::Static(resolved)
            }
            ReplicationPolicy::Adaptive(cfg) => {
                anyhow::ensure!(
                    cfg.window_s.is_finite() && cfg.window_s > 0.0,
                    "adaptive replication needs a positive, finite window, got {}",
                    cfg.window_s
                );
                anyhow::ensure!(
                    cfg.headroom.is_finite() && cfg.headroom > 0.0,
                    "adaptive replication needs positive, finite headroom, got {}",
                    cfg.headroom
                );
                anyhow::ensure!(
                    cfg.growth_factor.is_finite() && cfg.growth_factor >= 1.0,
                    "adaptive growth_factor must be finite and >= 1 \
                     (growth can never be cheaper than repair), got {}",
                    cfg.growth_factor
                );
                Mode::Adaptive(*cfg)
            }
        };
        let targets = match &mode {
            Mode::Static(t) => t.clone(),
            _ => vec![0; n],
        };
        Ok(ReplicaController {
            mode,
            prewarm_s: prewarm_s.to_vec(),
            targets,
            seen: vec![false; n],
            arrivals: vec![VecDeque::new(); n],
            reloads: vec![VecDeque::new(); n],
        })
    }

    /// `None`-policy controllers are inert: the simulator skips every
    /// observation and planning call, keeping the pre-replication code
    /// path untouched.
    pub fn is_off(&self) -> bool {
        matches!(self.mode, Mode::Off)
    }

    /// Current replica targets (zeros unless Static/grown-Adaptive).
    pub fn targets(&self) -> &[usize] {
        &self.targets
    }

    /// Record one arrival for `net` at virtual time `t`.
    pub fn note_arrival(&mut self, net: usize, t: f64) {
        self.seen[net] = true;
        if let Mode::Adaptive(_) = self.mode {
            self.arrivals[net].push_back(t);
        }
    }

    /// Record a blocking weight reload `net` paid at `t` costing `cost_s`.
    pub fn note_reload(&mut self, net: usize, t: f64, cost_s: f64) {
        if let Mode::Adaptive(_) = self.mode {
            self.reloads[net].push_back((t, cost_s));
        }
    }

    /// A pre-warm for `net` was applied: its windowed reload spend is
    /// consumed (each pre-warm must be funded by fresh reload pain, so a
    /// single burst of reloads cannot trigger a storm of pre-warms).
    pub fn prewarmed(&mut self, net: usize) {
        self.reloads[net].clear();
    }

    fn prune(&mut self, now: f64, window_s: f64) {
        let horizon = now - window_s;
        for q in &mut self.arrivals {
            while q.front().is_some_and(|&t| t < horizon) {
                q.pop_front();
            }
        }
        for q in &mut self.reloads {
            while q.front().is_some_and(|&(t, _)| t < horizon) {
                q.pop_front();
            }
        }
    }

    /// Plan the next residency change, if any. Deterministic: networks
    /// are examined in index order; pre-warm victims are chosen by the
    /// same `(busy_until, open members, id)` order placement uses, and a
    /// drain (which is free) drops the lowest-id open-free holder. Only
    /// workers with **no open batch** are ever touched, so issued
    /// admission quotes stay upper bounds.
    pub fn plan(
        &mut self,
        now: f64,
        replicas: &ReplicaSet,
        workers: &[VWorker],
    ) -> Option<ReplicaAction> {
        // Copy the adaptive knobs out so the arm below can update the
        // windows and targets without fighting the borrow of `mode`; the
        // static arm never mutates the controller, so it runs in place.
        let cfg = match &self.mode {
            Mode::Off => return None,
            Mode::Static(targets) => return Self::plan_static(targets, replicas, workers),
            Mode::Adaptive(cfg) => *cfg,
        };
        self.prune(now, cfg.window_s);
        let cap = if cfg.max_replicas == 0 {
            workers.len()
        } else {
            cfg.max_replicas.min(workers.len())
        };
        // Drain first: cold networks (live once, silent for a full
        // window) give their workers back as pre-warm targets.
        for net in 0..self.targets.len() {
            if self.seen[net] && self.arrivals[net].is_empty() && replicas.count(net) > 0 {
                self.targets[net] = 0;
                if let Some(&w) = replicas
                    .holders(net)
                    .iter()
                    .find(|&&w| workers[w].open.is_none())
                {
                    return Some(ReplicaAction::Drain { worker: w, net });
                }
            }
        }
        // Repair/grow: a homeless network whose windowed reload spend
        // covers one pre-warm gets its residency restored; a resident
        // one must show `growth_factor ×` that pain to widen its lane.
        // The replica lands on the least-loaded open-free worker that is
        // empty or holds a network no hotter (by windowed arrivals) than
        // the one growing.
        for net in 0..self.targets.len() {
            let spend: f64 = self.reloads[net].iter().map(|&(_, c)| c).sum();
            let count = replicas.count(net);
            let need = if count == 0 {
                cfg.headroom * self.prewarm_s[net]
            } else {
                cfg.growth_factor * cfg.headroom * self.prewarm_s[net]
            };
            if spend < need || count >= cap {
                continue;
            }
            let hotness = self.arrivals[net].len();
            let eligible = (0..workers.len()).filter(|&w| {
                workers[w].open.is_none()
                    && !replicas.is_holder(w, net)
                    && match replicas.resident(w) {
                        None => true,
                        Some(y) => self.arrivals[y].len() <= hotness,
                    }
            });
            if let Some(w) = least_loaded(workers, eligible) {
                self.targets[net] = count + 1;
                return Some(ReplicaAction::Prewarm { worker: w, net });
            }
        }
        None
    }

    /// Static planning: pre-warm the first below-target network onto the
    /// least-loaded worker that is empty or holds a network strictly
    /// above its own target. Pure — no controller state involved.
    fn plan_static(
        targets: &[usize],
        replicas: &ReplicaSet,
        workers: &[VWorker],
    ) -> Option<ReplicaAction> {
        for (net, &target) in targets.iter().enumerate() {
            if replicas.count(net) >= target {
                continue;
            }
            let eligible = (0..workers.len()).filter(|&w| {
                workers[w].open.is_none()
                    && !replicas.is_holder(w, net)
                    && match replicas.resident(w) {
                        None => true,
                        Some(y) => replicas.count(y) > targets[y],
                    }
            });
            if let Some(w) = least_loaded(workers, eligible) {
                return Some(ReplicaAction::Prewarm { worker: w, net });
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::vworker::OpenBatch;

    fn fleet(n: usize) -> Vec<VWorker> {
        (0..n).map(VWorker::new).collect()
    }

    #[test]
    fn replica_set_tracks_loads_and_evicts() {
        let mut rs = ReplicaSet::new(3, 4);
        assert_eq!(rs.count(0), 0);
        rs.on_load(2, 0);
        rs.on_load(1, 0);
        assert_eq!(rs.holders(0), &[1, 2], "holders stay sorted by id");
        assert!(rs.is_holder(2, 0));
        assert_eq!(rs.resident(1), Some(0));
        // Loading a different network on worker 2 evicts net 0 there.
        rs.on_load(2, 1);
        assert_eq!(rs.holders(0), &[1]);
        assert_eq!(rs.holders(1), &[2]);
        // Re-loading the same network is a no-op.
        rs.on_load(2, 1);
        assert_eq!(rs.holders(1), &[2]);
        rs.on_evict(1);
        assert_eq!(rs.count(0), 0);
        assert_eq!(rs.resident(1), None);
        // Evicting an empty worker is a no-op.
        rs.on_evict(3);
        assert_eq!(rs.resident(3), None);
    }

    #[test]
    fn fold_reproduces_a_live_set() {
        let events = [
            (0, 1, ResidencyChange::Load),
            (1, 1, ResidencyChange::Load),
            (0, 1, ResidencyChange::Evict),
            (0, 0, ResidencyChange::Load),
            (2, 2, ResidencyChange::Load),
        ]
        .map(|(worker, net, change)| ResidencyEvent {
            t_s: 0.0,
            worker,
            net,
            change,
            cause: ResidencyCause::Batch,
        });
        let rs = ReplicaSet::fold(3, 3, &events);
        assert_eq!(rs.holders(0), &[0]);
        assert_eq!(rs.holders(1), &[1]);
        assert_eq!(rs.holders(2), &[2]);
    }

    #[test]
    fn policy_specs_parse_and_label() {
        assert_eq!(ReplicationPolicy::parse("none").unwrap(), ReplicationPolicy::None);
        assert_eq!(
            ReplicationPolicy::parse("adaptive").unwrap(),
            ReplicationPolicy::Adaptive(AdaptiveConfig::default())
        );
        let ReplicationPolicy::Adaptive(cfg) = ReplicationPolicy::parse("adaptive:40").unwrap()
        else {
            panic!("adaptive:40 must parse as adaptive");
        };
        assert!((cfg.window_s - 0.04).abs() < 1e-12);
        assert_eq!(
            ReplicationPolicy::parse("static:2").unwrap(),
            ReplicationPolicy::Static {
                targets: vec![("*".to_string(), 2)]
            }
        );
        assert_eq!(
            ReplicationPolicy::parse("static:vgg11=2,mobilenetv1=1").unwrap(),
            ReplicationPolicy::Static {
                targets: vec![("vgg11".to_string(), 2), ("mobilenetv1".to_string(), 1)]
            }
        );
        for p in [
            ReplicationPolicy::None,
            ReplicationPolicy::Adaptive(AdaptiveConfig::default()),
            ReplicationPolicy::Static { targets: vec![] },
        ] {
            assert!(["none", "static", "adaptive"].contains(&p.label()));
        }
        for bad in [
            "", "static", "static:", "static:x", "static:a=b", "adaptive:0", "adaptive:x", "rand",
        ] {
            assert!(ReplicationPolicy::parse(bad).is_err(), "`{bad}` should not parse");
        }
    }

    #[test]
    fn static_targets_resolve_names_and_reject_unknowns() {
        let policy = ReplicationPolicy::Static {
            targets: vec![("*".to_string(), 1), ("a".to_string(), 2)],
        };
        let c = ReplicaController::new(&policy, &["a", "b"], &[1e-3, 1e-3], 4).unwrap();
        assert_eq!(c.targets(), &[2, 1], "explicit names override the wildcard");
        let bad = ReplicationPolicy::Static {
            targets: vec![("nope".to_string(), 1)],
        };
        assert!(ReplicaController::new(&bad, &["a", "b"], &[1e-3, 1e-3], 4).is_err());
        // Targets clamp to the fleet size.
        let big = ReplicationPolicy::Static {
            targets: vec![("a".to_string(), 9)],
        };
        let c = ReplicaController::new(&big, &["a", "b"], &[1e-3, 1e-3], 2).unwrap();
        assert_eq!(c.targets(), &[2, 0]);
    }

    #[test]
    fn off_controller_is_inert() {
        let mut c =
            ReplicaController::new(&ReplicationPolicy::None, &["a"], &[1e-3], 2).unwrap();
        assert!(c.is_off());
        let rs = ReplicaSet::new(1, 2);
        assert_eq!(c.plan(0.0, &rs, &fleet(2)), None);
    }

    #[test]
    fn static_plans_prewarms_up_to_target_without_stealing_below_target() {
        let policy = ReplicationPolicy::Static {
            targets: vec![("a".to_string(), 2), ("b".to_string(), 1)],
        };
        let mut c = ReplicaController::new(&policy, &["a", "b"], &[1e-3, 1e-3], 3).unwrap();
        let mut rs = ReplicaSet::new(2, 3);
        let workers = fleet(3);
        // Applies actions exactly as the simulator would: plan, apply, replan.
        let mut seen = Vec::new();
        while let Some(a) = c.plan(0.0, &rs, &workers) {
            let ReplicaAction::Prewarm { worker, net } = a else {
                panic!("static never drains");
            };
            rs.on_load(worker, net);
            seen.push((worker, net));
            assert!(seen.len() <= 3, "static planning must converge");
        }
        assert_eq!(rs.holders(0), &[0, 1], "net a reaches its target of 2");
        assert_eq!(rs.holders(1), &[2], "net b gets the remaining worker");
        // Fully-provisioned fleet: no worker is empty or above target, so
        // nothing more can be stolen even though a 4th deficit could exist.
        assert_eq!(c.plan(0.0, &rs, &workers), None);
    }

    #[test]
    fn static_never_touches_workers_with_open_batches() {
        let policy = ReplicationPolicy::Static {
            targets: vec![("a".to_string(), 1)],
        };
        let mut c = ReplicaController::new(&policy, &["a"], &[1e-3], 1).unwrap();
        let rs = ReplicaSet::new(1, 1);
        let mut workers = fleet(1);
        workers[0].open = Some(OpenBatch {
            net: 0,
            first_arrival_s: 0.0,
            deadline_s: 0.001,
            members: vec![(0, 0.0)],
        });
        assert_eq!(
            c.plan(0.0, &rs, &workers),
            None,
            "a quoted worker must never be pre-warmed"
        );
    }

    #[test]
    fn adaptive_repairs_cheap_grows_dear_and_clears_its_funding() {
        let policy = ReplicationPolicy::Adaptive(AdaptiveConfig {
            window_s: 1.0,
            headroom: 1.0,
            growth_factor: 3.0,
            max_replicas: 0,
        });
        let mut c = ReplicaController::new(&policy, &["a", "b"], &[1e-3, 1e-3], 2).unwrap();
        let mut rs = ReplicaSet::new(2, 2);
        let workers = fleet(2);
        c.note_arrival(0, 0.0);
        // No reload pain yet: nothing to do.
        assert_eq!(c.plan(0.01, &rs, &workers), None);
        // One blocking reload covers one pre-warm: repair (count 0 → 1).
        c.note_reload(0, 0.02, 1e-3);
        let a = c.plan(0.03, &rs, &workers);
        assert_eq!(a, Some(ReplicaAction::Prewarm { worker: 0, net: 0 }));
        rs.on_load(0, 0);
        c.prewarmed(0);
        // Funding consumed: no second pre-warm until new reload pain.
        assert_eq!(c.plan(0.04, &rs, &workers), None);
        assert_eq!(c.targets()[0], 1);
        // A resident network needs growth_factor × the pain to widen: one
        // fresh reload is not enough...
        c.note_reload(0, 0.05, 1e-3);
        assert_eq!(c.plan(0.06, &rs, &workers), None, "growth is dearer than repair");
        // ...three reloads' worth is.
        c.note_reload(0, 0.07, 1e-3);
        c.note_reload(0, 0.08, 1e-3);
        let a = c.plan(0.09, &rs, &workers);
        assert_eq!(a, Some(ReplicaAction::Prewarm { worker: 1, net: 0 }));
        rs.on_load(1, 0);
        c.prewarmed(0);
        assert_eq!(c.targets()[0], 2);
        // Fully replicated: even heavy fresh pain cannot grow past the fleet.
        for i in 0..4 {
            c.note_reload(0, 0.1 + i as f64 * 0.01, 1e-3);
        }
        assert_eq!(c.plan(0.2, &rs, &workers), None);
    }

    #[test]
    fn adaptive_never_steals_a_hotter_networks_worker() {
        let policy = ReplicationPolicy::Adaptive(AdaptiveConfig {
            window_s: 1.0,
            ..AdaptiveConfig::default()
        });
        let mut c = ReplicaController::new(&policy, &["hot", "cold"], &[1e-3, 1e-3], 1).unwrap();
        let mut rs = ReplicaSet::new(2, 1);
        let workers = fleet(1);
        for i in 0..5 {
            c.note_arrival(0, i as f64 * 0.01);
        }
        rs.on_load(0, 0);
        c.note_arrival(1, 0.05);
        c.note_reload(1, 0.05, 1e-3);
        assert_eq!(
            c.plan(0.06, &rs, &workers),
            None,
            "the only worker holds a hotter network: cold must not steal it"
        );
    }

    #[test]
    fn adaptive_drains_cold_networks_after_a_silent_window() {
        let policy = ReplicationPolicy::Adaptive(AdaptiveConfig {
            window_s: 0.01,
            ..AdaptiveConfig::default()
        });
        let mut c = ReplicaController::new(&policy, &["a", "b"], &[1e-3, 1e-3], 2).unwrap();
        let mut rs = ReplicaSet::new(2, 2);
        let workers = fleet(2);
        c.note_arrival(0, 0.0);
        c.note_arrival(1, 0.0);
        rs.on_load(0, 0);
        rs.on_load(1, 1);
        // Inside the window both networks are live: no drains.
        assert_eq!(c.plan(0.005, &rs, &workers), None);
        // A full silent window later, both are cold and drain in index order.
        assert_eq!(
            c.plan(0.1, &rs, &workers),
            Some(ReplicaAction::Drain { worker: 0, net: 0 })
        );
        rs.on_evict(0);
        assert_eq!(
            c.plan(0.1, &rs, &workers),
            Some(ReplicaAction::Drain { worker: 1, net: 1 })
        );
        rs.on_evict(1);
        assert_eq!(c.plan(0.1, &rs, &workers), None, "nothing left to drain");
        assert_eq!(c.targets(), &[0, 0]);
    }
}
