//! Tier-1 pins for the fleet-level weight-replication subsystem:
//!
//! * `ReplicationPolicy::None` replays bitwise-identically to the
//!   pre-replication model under every placement policy — including
//!   against `Static` with an empty target map, which runs the whole
//!   controller plumbing but takes no action, pinning that the
//!   subsystem's presence alone perturbs nothing;
//! * K distinct networks cost exactly K engine plans at any fleet size
//!   and replica count — replication copies weights, never re-plans;
//! * on a pinned skewed trace over 3 workers, adaptive replication
//!   strictly reduces blocking weight reloads and never loses goodput
//!   versus single-residency `NetworkAffinity` (the same scenario is
//!   pinned in `benches/hotpath.rs`);
//! * static pinning holds its replica targets; adaptive drains cold
//!   networks' replicas once they fall silent for a window.

use pimflow::cfg::presets;
use pimflow::coordinator::{
    AdaptiveConfig, Arrival, Placement, ReplicationPolicy, SimRequest, SimServeConfig,
};
use pimflow::explore::trace::{mixed_trace, replay};
use pimflow::nn::{zoo, Network};
use pimflow::sim::Engine;

fn engine() -> Engine {
    Engine::compact(presets::lpddr5())
}

/// The pinned skewed workload: one hot network (mobilenetv1, every other
/// request) and three cold ones cycling behind it, arrivals spaced far
/// apart (25 ms ≫ any makespan or weight stream) so the fleet drains
/// between requests and the dynamics are pure placement/residency. On 3
/// workers under single-residency affinity the three cold networks cycle
/// through two cold slots in LRU order — the pathological pattern where
/// every cold arrival finds its weights evicted.
fn skewed_nets() -> Vec<Network> {
    ["mobilenetv1", "vgg11", "resnet18", "vgg13"]
        .iter()
        .map(|n| zoo::by_name(n, 100).unwrap())
        .collect()
}

fn skewed_trace(n: usize) -> Vec<SimRequest> {
    (0..n)
        .map(|j| SimRequest {
            id: j as u64,
            net: if j % 2 == 0 { 0 } else { 1 + (j / 2) % 3 },
            arrival_s: j as f64 * 0.025,
        })
        .collect()
}

fn base_cfg() -> SimServeConfig {
    SimServeConfig {
        slo_s: 1e6,
        max_batch: 8,
        max_wait_s: 0.001,
        workers: 3,
        placement: Placement::NetworkAffinity,
        ..SimServeConfig::default()
    }
}

#[test]
fn replication_none_is_bitwise_identical_to_an_inert_controller_under_every_placement() {
    // `None` short-circuits the controller; `Static` with an empty target
    // map runs every controller entry point and never acts. Bitwise
    // equality between the two, per placement policy and fleet size, pins
    // that the replication subsystem is transparent when it does nothing
    // — i.e., `None` is exactly the pre-replication model.
    let (nets, trace) =
        mixed_trace(&["mobilenetv1", "vgg11", "resnet18"], 180, Arrival::Poisson(2000.0), 2026)
            .unwrap();
    for workers in [1usize, 3] {
        for placement in Placement::ALL {
            let cfg = |replication: ReplicationPolicy| SimServeConfig {
                workers,
                placement,
                replication,
                slo_s: 0.05,
                max_batch: 16,
                max_wait_s: 0.001,
                ..SimServeConfig::default()
            };
            let none = replay(&engine(), &nets, &trace, cfg(ReplicationPolicy::None)).unwrap();
            let inert = replay(
                &engine(),
                &nets,
                &trace,
                cfg(ReplicationPolicy::Static { targets: vec![] }),
            )
            .unwrap();
            let label = format!("{} workers / {}", workers, placement.label());
            assert_eq!(none.accepted(), inert.accepted(), "{label}: accepted");
            assert_eq!(none.coalesced(), inert.coalesced(), "{label}: coalesced");
            assert_eq!(none.rejected(), inert.rejected(), "{label}: rejected");
            assert_eq!(none.batches(), inert.batches(), "{label}: batches");
            assert_eq!(none.reloads(), inert.reloads(), "{label}: reloads");
            assert_eq!(
                none.span_s.to_bits(),
                inert.span_s.to_bits(),
                "{label}: span"
            );
            assert_eq!(none.completions.len(), inert.completions.len());
            for (a, b) in none.completions.iter().zip(&inert.completions) {
                assert_eq!(a.id, b.id, "{label}: completion order");
                assert_eq!(a.worker, b.worker, "{label}: worker of request {}", a.id);
                assert_eq!(
                    a.completion_s.to_bits(),
                    b.completion_s.to_bits(),
                    "{label}: completion time of request {}",
                    a.id
                );
            }
            assert_eq!(none.replica_holders, inert.replica_holders, "{label}: residency");
            for r in [&none, &inert] {
                assert_eq!(r.prewarms(), 0, "{label}: no policy may pre-warm here");
                assert_eq!(r.drains(), 0, "{label}: no policy may drain here");
            }
        }
    }
}

#[test]
fn k_networks_cost_k_plans_at_any_fleet_size_and_replica_count() {
    let nets = skewed_nets();
    let trace = skewed_trace(120);
    let policies = [
        ReplicationPolicy::None,
        ReplicationPolicy::Static {
            targets: vec![("mobilenetv1".to_string(), 2), ("*".to_string(), 1)],
        },
        ReplicationPolicy::Adaptive(AdaptiveConfig::default()),
    ];
    for workers in [1usize, 3, 5] {
        for policy in &policies {
            let eng = engine();
            let cfg = SimServeConfig {
                workers,
                replication: policy.clone(),
                ..base_cfg()
            };
            let r = replay(&eng, &nets, &trace, cfg).unwrap();
            assert_eq!(
                r.plans_computed,
                nets.len() as u64,
                "{workers} workers / {}: replication must copy weights, never re-plan",
                policy.label()
            );
            assert_eq!(eng.cache_stats().misses, nets.len() as u64);
            assert_eq!(r.accepted(), 120, "generous SLO accepts everything");
        }
    }
}

#[test]
fn adaptive_replication_beats_single_residency_affinity_on_the_pinned_skewed_trace() {
    // The headline pin: same trace, same 3-worker affinity fleet; the only
    // difference is the adaptive replica controller. Single residency
    // churns — every cold arrival finds its weights evicted (three cold
    // networks cycling over the two non-hot slots in LRU order) — while
    // the controller's repairs re-stream evicted weights onto idle
    // workers between arrivals, so a strict share of cold batches find
    // their weights already resident.
    let eng = engine();
    let nets = skewed_nets();
    let trace = skewed_trace(240);
    let none = replay(
        &eng,
        &nets,
        &trace,
        SimServeConfig {
            replication: ReplicationPolicy::None,
            ..base_cfg()
        },
    )
    .unwrap();
    let adaptive = replay(
        &eng,
        &nets,
        &trace,
        SimServeConfig {
            replication: ReplicationPolicy::Adaptive(AdaptiveConfig::default()),
            ..base_cfg()
        },
    )
    .unwrap();

    // Both runs serve the full trace under the generous SLO.
    for (label, r) in [("none", &none), ("adaptive", &adaptive)] {
        assert_eq!(r.offered(), 240, "{label}");
        assert_eq!(r.accepted(), 240, "{label}");
        assert_eq!(r.completed(), 240, "{label}");
    }
    // Sanity: single residency really is in the churn regime.
    assert!(
        none.reloads() >= 30,
        "expected heavy cold churn under single residency, got {} reloads",
        none.reloads()
    );
    assert_eq!(none.prewarms(), 0);
    // The acceptance pin: strictly fewer blocking reloads, no goodput
    // loss, and the savings actually came from pre-warmed replicas.
    assert!(
        adaptive.reloads() < none.reloads(),
        "adaptive reloads {} not strictly below single-residency {}",
        adaptive.reloads(),
        none.reloads()
    );
    assert!(
        adaptive.goodput() >= none.goodput(),
        "adaptive goodput {} fell below single-residency {}",
        adaptive.goodput(),
        none.goodput()
    );
    assert!(adaptive.prewarms() > 0, "the controller must have pre-warmed");
    // The hot network's lane is protected: it never pays more reloads
    // than under single residency.
    assert!(
        adaptive.per_net[0].reloads <= none.per_net[0].reloads,
        "the controller made the hot lane worse: {} vs {}",
        adaptive.per_net[0].reloads,
        none.per_net[0].reloads
    );
    // One engine, both replays: still one plan per network.
    assert_eq!(eng.cache_stats().misses, nets.len() as u64);
}

#[test]
fn static_targets_hold_their_replica_counts_across_the_trace() {
    let eng = engine();
    let nets = skewed_nets();
    let trace = skewed_trace(120);
    let cfg = SimServeConfig {
        replication: ReplicationPolicy::Static {
            targets: vec![("mobilenetv1".to_string(), 2), ("*".to_string(), 0)],
        },
        workers: 4,
        ..base_cfg()
    };
    let r = replay(&eng, &nets, &trace, cfg).unwrap();
    // The pinned double lane makes the hot network reload-proof: its two
    // replicas were pre-warmed before its first batch, and whenever a
    // cold fallback steals one, the controller restores it at the next
    // offer — always before both replicas can be lost, so every hot
    // batch finds resident weights.
    assert_eq!(r.per_net[0].reloads, 0, "a pinned hot lane never reloads");
    assert!(r.prewarms() >= 2, "initial provisioning alone takes 2 pre-warms");
    // At least one hot replica survives to end of trace (a final-offer
    // steal can leave the second deficit unrestored).
    assert!(
        !r.replica_holders[0].is_empty(),
        "hot network lost all replicas: {:?}",
        r.replica_holders
    );
    assert_eq!(r.completed(), 120);
}

#[test]
fn adaptive_drains_replicas_of_networks_that_fall_silent() {
    let eng = engine();
    let nets: Vec<Network> = ["mobilenetv1", "vgg11"]
        .iter()
        .map(|n| zoo::by_name(n, 100).unwrap())
        .collect();
    // vgg11 is live early, then falls silent; mobilenetv1 keeps arriving,
    // driving controller steps past the silent window.
    let mut trace = Vec::new();
    for j in 0..6u64 {
        trace.push(SimRequest {
            id: j,
            net: (j % 2) as usize,
            arrival_s: j as f64 * 0.01,
        });
    }
    for j in 6..30u64 {
        trace.push(SimRequest {
            id: j,
            net: 0,
            arrival_s: 0.06 + (j - 6) as f64 * 0.01,
        });
    }
    let cfg = SimServeConfig {
        workers: 2,
        placement: Placement::NetworkAffinity,
        replication: ReplicationPolicy::Adaptive(AdaptiveConfig {
            window_s: 0.05,
            ..AdaptiveConfig::default()
        }),
        slo_s: 1e6,
        max_batch: 4,
        max_wait_s: 0.001,
        ..SimServeConfig::default()
    };
    let r = replay(&eng, &nets, &trace, cfg).unwrap();
    assert!(r.drains() >= 1, "the silent network's replica must drain");
    assert!(
        r.replica_holders[1].is_empty(),
        "vgg11 must hold nothing at end of trace: {:?}",
        r.replica_holders
    );
    // Under policy None the weights would have squatted on their worker.
    let none = replay(
        &eng,
        &nets,
        &trace,
        SimServeConfig {
            replication: ReplicationPolicy::None,
            workers: 2,
            placement: Placement::NetworkAffinity,
            slo_s: 1e6,
            max_batch: 4,
            max_wait_s: 0.001,
            ..SimServeConfig::default()
        },
    )
    .unwrap();
    assert!(
        !none.replica_holders[1].is_empty(),
        "without a controller the cold weights stay resident"
    );
}
