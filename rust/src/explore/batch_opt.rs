//! Batch-size auto-tuning.
//!
//! §II-C: *"by setting a suitable batch size n that considers the latency
//! to get the inference result"* — throughput grows monotonically with n
//! while the first result's latency (fill + whole-batch residency of part
//! 1) also grows. This module finds the smallest batch meeting a target
//! fraction of asymptotic throughput, and the largest batch meeting a
//! result-latency SLO.
//!
//! The probes run through the [`Engine`], so the chip/plan/DDM work is
//! computed once and every batch probe pays only the pipeline simulation.

use anyhow::Result;

use crate::nn::Network;
use crate::sim::engine::{Design, Engine};

/// One evaluated batch point.
#[derive(Debug, Clone)]
pub struct BatchPoint {
    pub batch: u32,
    pub throughput_fps: f64,
    /// Latency until the *whole batch* completes, s (the paper's "latency
    /// to get the inference result" is bounded by this).
    pub batch_latency_s: f64,
}

fn eval(engine: &Engine, design: Design, net: &Network, batch: u32) -> Result<BatchPoint> {
    let r = engine.system_report(design, net, batch)?;
    Ok(BatchPoint {
        batch,
        throughput_fps: r.throughput_fps,
        batch_latency_s: r.pipeline.makespan_ns * 1e-9,
    })
}

/// Smallest batch on the probe ladder (powers of two clamped to
/// `max_batch`) whose throughput reaches `frac` of the throughput at
/// `max_batch`. Always terminates: the ladder ends at `max_batch`, whose
/// point is returned as the asymptote whenever no smaller batch reaches
/// the target — including `frac >= 1.0` (nothing strictly beats the
/// asymptote) and `max_batch == 1` (the ladder is a single rung). The
/// returned batch never exceeds `max_batch`, even when it is not a power
/// of two.
pub fn min_batch_for_throughput(
    engine: &Engine,
    design: Design,
    net: &Network,
    frac: f64,
    max_batch: u32,
) -> Result<BatchPoint> {
    anyhow::ensure!(max_batch >= 1, "max_batch must be >= 1");
    let asymptote = eval(engine, design, net, max_batch)?.throughput_fps;
    let mut b = 1u32;
    loop {
        let p = eval(engine, design, net, b)?;
        if p.throughput_fps >= frac * asymptote || b >= max_batch {
            return Ok(p);
        }
        b = b.saturating_mul(2).min(max_batch);
    }
}

/// One row of a multi-network batch-tuning sweep.
#[derive(Debug, Clone)]
pub struct TunedNetwork {
    pub network: String,
    pub weights: u64,
    pub point: BatchPoint,
}

/// Tune the smallest batch reaching `frac` of asymptotic throughput for
/// every network in `nets` (the zoo's network axis applied to the batch
/// auto-tuner). Rows come back in input order; each network's probe
/// ladder reuses one cached plan.
pub fn tune_networks(
    engine: &Engine,
    design: Design,
    nets: &[Network],
    frac: f64,
    max_batch: u32,
) -> Result<Vec<TunedNetwork>> {
    nets.iter()
        .map(|net| {
            Ok(TunedNetwork {
                network: net.name.clone(),
                weights: net.total_weights(),
                point: min_batch_for_throughput(engine, design, net, frac, max_batch)?,
            })
        })
        .collect()
}

/// Largest power-of-two batch (≤ `max_batch`) whose full-batch latency
/// stays under `slo_s`; `None` if even batch 1 misses it (or
/// `max_batch == 0`). The ladder stops at the first violation — sound
/// because full-batch latency is monotone in batch size (asserted by
/// `latency_monotone_in_batch` below).
pub fn max_batch_for_latency(
    engine: &Engine,
    design: Design,
    net: &Network,
    slo_s: f64,
    max_batch: u32,
) -> Result<Option<BatchPoint>> {
    let mut best: Option<BatchPoint> = None;
    let mut b = 1u32;
    while b <= max_batch {
        let p = eval(engine, design, net, b)?;
        if p.batch_latency_s <= slo_s {
            best = Some(p);
        } else {
            break; // latency is monotone in batch
        }
        b *= 2;
    }
    Ok(best)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cfg::presets;
    use crate::nn::resnet;

    fn engine() -> Engine {
        Engine::compact(presets::lpddr5())
    }

    #[test]
    fn min_batch_hits_fraction() {
        let net = resnet::resnet18(100);
        let eng = engine();
        let p = min_batch_for_throughput(&eng, Design::CompactDdm, &net, 0.8, 1024).unwrap();
        let asym = eval(&eng, Design::CompactDdm, &net, 1024)
            .unwrap()
            .throughput_fps;
        assert!(p.throughput_fps >= 0.8 * asym);
        // and the previous power of two must miss it (minimality)
        if p.batch > 1 {
            let prev = eval(&eng, Design::CompactDdm, &net, p.batch / 2)
                .unwrap()
                .throughput_fps;
            assert!(prev < 0.8 * asym);
        }
        // the whole probe ladder shares one plan
        assert_eq!(eng.cache_stats().misses, 1);
    }

    #[test]
    fn tune_networks_covers_the_axis_and_shares_plans() {
        let nets = [
            crate::nn::zoo::by_name("mobilenetv1", 100).unwrap(),
            resnet::resnet18(100),
        ];
        let eng = engine();
        let rows = tune_networks(&eng, Design::CompactDdm, &nets, 0.5, 64).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].network, "mobilenetv1");
        assert!(rows.iter().all(|r| r.point.throughput_fps > 0.0));
        // one plan per network, however many batch probes each needed
        assert_eq!(eng.cache_stats().misses, 2);
    }

    #[test]
    fn saturating_frac_and_unit_cap_terminate_at_the_asymptote() {
        let net = resnet::resnet18(100);
        let eng = engine();
        // frac >= 1.0: no batch strictly beats the asymptote, so the
        // ladder must run off its end and return the max_batch point.
        let p = min_batch_for_throughput(&eng, Design::CompactDdm, &net, 1.5, 16).unwrap();
        assert_eq!(p.batch, 16);
        let asym = eval(&eng, Design::CompactDdm, &net, 16).unwrap();
        assert_eq!(p.throughput_fps.to_bits(), asym.throughput_fps.to_bits());
        // max_batch == 1: the ladder is one rung.
        let p1 = min_batch_for_throughput(&eng, Design::CompactDdm, &net, 1.5, 1).unwrap();
        assert_eq!(p1.batch, 1);
        // both together
        let p11 = min_batch_for_throughput(&eng, Design::CompactDdm, &net, 2.0, 1).unwrap();
        assert_eq!(p11.batch, 1);
        assert!(min_batch_for_throughput(&eng, Design::CompactDdm, &net, 0.5, 0).is_err());
    }

    #[test]
    fn probe_ladder_never_exceeds_a_non_power_of_two_cap() {
        let net = resnet::resnet18(100);
        let eng = engine();
        // cap 3: the ladder is 1, 2, 3 — never 4.
        let p = min_batch_for_throughput(&eng, Design::CompactDdm, &net, 10.0, 3).unwrap();
        assert_eq!(p.batch, 3, "clamped to the cap, not the next power of two");
    }

    #[test]
    fn latency_slo_binds() {
        let net = resnet::resnet18(100);
        let eng = engine();
        // generous SLO: some batch fits; tiny SLO: none does
        let some = max_batch_for_latency(&eng, Design::CompactDdm, &net, 1.0, 256).unwrap();
        assert!(some.is_some());
        let none = max_batch_for_latency(&eng, Design::CompactDdm, &net, 1e-9, 256).unwrap();
        assert!(none.is_none());
    }

    #[test]
    fn latency_monotone_in_batch() {
        let net = resnet::resnet18(100);
        let eng = engine();
        let mut prev = 0.0;
        for b in [1u32, 4, 16, 64] {
            let p = eval(&eng, Design::CompactDdm, &net, b).unwrap();
            assert!(p.batch_latency_s >= prev);
            prev = p.batch_latency_s;
        }
    }
}
