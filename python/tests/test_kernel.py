"""L1 kernel correctness: Pallas crossbar matmul vs pure-jnp oracles.

The core signal: with a lossless ADC the kernel must equal the exact integer
matmul bit-for-bit; with a saturating ADC it must equal the oracle that
models the same saturation. Hypothesis sweeps shapes and crossbar configs.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.crossbar import (
    crossbar_matmul,
    crossbar_params_ok,
    lossless_adc_bits,
    vmem_footprint_bytes,
)
from compile.kernels.ref import crossbar_matmul_ref, int_matmul_ref


def rand_xw(m, k, n, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(0, 256, (m, k), dtype=np.int32))
    w = jnp.asarray(rng.integers(-128, 128, (k, n), dtype=np.int32))
    return x, w


class TestLossless:
    def test_exact_vs_int_matmul(self):
        x, w = rand_xw(13, 200, 37)
        out = crossbar_matmul(x, w)
        assert (out == int_matmul_ref(x, w)).all()

    def test_exact_vs_ref(self):
        x, w = rand_xw(13, 200, 37, seed=3)
        assert (crossbar_matmul(x, w) == crossbar_matmul_ref(x, w)).all()

    def test_single_subarray(self):
        x, w = rand_xw(8, 128, 32, seed=5)
        assert (crossbar_matmul(x, w) == int_matmul_ref(x, w)).all()

    def test_k_smaller_than_subarray(self):
        x, w = rand_xw(4, 27, 16, seed=7)  # stem conv shape: K=27 padded to 128
        assert (crossbar_matmul(x, w) == int_matmul_ref(x, w)).all()

    def test_extreme_values(self):
        # All-max activations against all-min/max weights: worst-case ranges.
        x = jnp.full((4, 128), 255, jnp.int32)
        for wval in (-128, 127):
            w = jnp.full((128, 8), wval, jnp.int32)
            assert (crossbar_matmul(x, w) == int_matmul_ref(x, w)).all()

    def test_zero_activations(self):
        x = jnp.zeros((4, 128), jnp.int32)
        _, w = rand_xw(4, 128, 8, seed=11)
        assert (crossbar_matmul(x, w) == 0).all()

    def test_identity_weight(self):
        x, _ = rand_xw(8, 64, 64, seed=13)
        w = jnp.eye(64, dtype=jnp.int32)
        assert (crossbar_matmul(x, w) == x).all()

    @pytest.mark.parametrize("cell_bits", [1, 2, 4, 8])
    def test_all_cell_widths(self, cell_bits):
        x, w = rand_xw(8, 128, 16, seed=cell_bits)
        adc = lossless_adc_bits(cell_bits, 128)
        out = crossbar_matmul(x, w, cell_bits=cell_bits, adc_bits=adc)
        assert (out == int_matmul_ref(x, w)).all()

    @pytest.mark.parametrize("rows", [32, 64, 128, 256])
    def test_subarray_sizes(self, rows):
        x, w = rand_xw(8, 300, 16, seed=rows)
        adc = lossless_adc_bits(2, rows)
        out = crossbar_matmul(x, w, subarray_rows=rows, adc_bits=adc)
        assert (out == int_matmul_ref(x, w)).all()


class TestSaturatingAdc:
    def test_matches_ref_when_lossy(self):
        x, w = rand_xw(13, 200, 37, seed=17)
        out = crossbar_matmul(x, w, adc_bits=4)
        ref = crossbar_matmul_ref(x, w, adc_bits=4)
        assert (out == ref).all()
        assert (out != int_matmul_ref(x, w)).any()  # saturation visible

    def test_saturation_bounds_error_one_sided(self):
        # Clipping partial sums can only shrink the positive contribution of
        # the offset-encoded planes, so lossy <= lossless after offset fix
        # is not guaranteed per element — but results must be deterministic.
        x, w = rand_xw(8, 128, 8, seed=19)
        a = crossbar_matmul(x, w, adc_bits=5)
        b = crossbar_matmul(x, w, adc_bits=5)
        assert (a == b).all()

    def test_lossless_threshold(self):
        # adc_bits exactly at the lossless boundary for (2, 128): max partial
        # is 128*3 = 384 -> 9 bits. 9 must be exact, 8 may differ.
        assert lossless_adc_bits(2, 128) == 9
        x = jnp.full((2, 128), 255, jnp.int32)
        w = jnp.full((128, 4), 127, jnp.int32)
        assert (crossbar_matmul(x, w, adc_bits=9) == int_matmul_ref(x, w)).all()
        assert (crossbar_matmul(x, w, adc_bits=8) != int_matmul_ref(x, w)).any()


class TestValidation:
    def test_rejects_bad_rank(self):
        with pytest.raises(ValueError):
            crossbar_matmul(jnp.zeros((2, 2, 2), jnp.int32), jnp.zeros((2, 2), jnp.int32))

    def test_rejects_mismatch(self):
        with pytest.raises(ValueError):
            crossbar_matmul(jnp.zeros((2, 3), jnp.int32), jnp.zeros((4, 2), jnp.int32))

    def test_rejects_bad_config(self):
        x, w = rand_xw(2, 8, 2)
        with pytest.raises(ValueError):
            crossbar_matmul(x, w, cell_bits=3)
        with pytest.raises(ValueError):
            crossbar_matmul(x, w, adc_bits=0)

    def test_params_ok(self):
        assert crossbar_params_ok(2, 9, 128)
        assert not crossbar_params_ok(3, 9, 128)
        assert not crossbar_params_ok(2, 0, 128)
        assert not crossbar_params_ok(2, 9, 0)


class TestVmemEstimate:
    def test_footprint_under_budget(self):
        total, parts = vmem_footprint_bytes(1152, block_m=64, block_n=32)
        assert total < 16 * 1024 * 1024  # TPU VMEM budget
        assert set(parts) == {"x_stripe", "w_panel", "acc_tile", "slice_tmp"}

    def test_scales_with_k(self):
        a, _ = vmem_footprint_bytes(128)
        b, _ = vmem_footprint_bytes(1280)
        assert b > a


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 300),
    n=st.integers(1, 70),
    seed=st.integers(0, 2**31),
)
def test_hypothesis_lossless_exact(m, k, n, seed):
    """Any shape, default config: kernel == exact integer matmul."""
    x, w = rand_xw(m, k, n, seed=seed)
    assert (crossbar_matmul(x, w) == int_matmul_ref(x, w)).all()


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 16),
    k=st.integers(1, 200),
    n=st.integers(1, 48),
    cell_bits=st.sampled_from([1, 2, 4]),
    adc_bits=st.integers(3, 12),
    seed=st.integers(0, 2**31),
)
def test_hypothesis_matches_ref_any_config(m, k, n, cell_bits, adc_bits, seed):
    """Pallas kernel == jnp oracle under every (possibly lossy) config."""
    x, w = rand_xw(m, k, n, seed=seed)
    out = crossbar_matmul(x, w, cell_bits=cell_bits, adc_bits=adc_bits)
    ref = crossbar_matmul_ref(x, w, cell_bits=cell_bits, adc_bits=adc_bits)
    assert (out == ref).all()


@settings(max_examples=10, deadline=None)
@given(
    blocks=st.tuples(st.sampled_from([4, 8, 16, 64]), st.sampled_from([8, 32, 64])),
    seed=st.integers(0, 2**31),
)
def test_hypothesis_block_shape_invariance(blocks, seed):
    """Tiling is an implementation detail: result must not depend on it."""
    bm, bn = blocks
    x, w = rand_xw(19, 160, 41, seed=seed)
    base = crossbar_matmul(x, w)
    tiled = crossbar_matmul(x, w, block_m=bm, block_n=bn)
    assert (base == tiled).all()


class TestFastPathDispatch:
    """§Perf iteration 1: the lossless-ADC fast path must be bit-identical
    to the faithful bit-serial kernel (see crossbar.py docstring)."""

    def test_fast_equals_bit_serial_default_config(self):
        x, w = rand_xw(19, 300, 41, seed=23)
        fast = crossbar_matmul(x, w)
        slow = crossbar_matmul(x, w, force_bit_serial=True)
        assert (fast == slow).all()

    @pytest.mark.parametrize("cell_bits", [1, 2, 4])
    def test_fast_equals_bit_serial_all_cells(self, cell_bits):
        x, w = rand_xw(8, 160, 16, seed=cell_bits + 100)
        adc = lossless_adc_bits(cell_bits, 128)
        fast = crossbar_matmul(x, w, cell_bits=cell_bits, adc_bits=adc)
        slow = crossbar_matmul(
            x, w, cell_bits=cell_bits, adc_bits=adc, force_bit_serial=True
        )
        assert (fast == slow).all()

    def test_lossy_adc_never_uses_fast_path(self):
        # A saturating ADC must produce the bit-serial result (≠ exact).
        x = jnp.full((2, 128), 255, jnp.int32)
        w = jnp.full((128, 4), 127, jnp.int32)
        lossy = crossbar_matmul(x, w, adc_bits=5)
        assert (lossy != int_matmul_ref(x, w)).any()


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(1, 24),
    k=st.integers(1, 200),
    n=st.integers(1, 48),
    seed=st.integers(0, 2**31),
)
def test_hypothesis_fast_path_equivalence(m, k, n, seed):
    x, w = rand_xw(m, k, n, seed=seed)
    assert (
        crossbar_matmul(x, w) == crossbar_matmul(x, w, force_bit_serial=True)
    ).all()
