"""ADC-precision study invariants."""

from compile.study_adc import study


class TestAdcStudy:
    def test_lossless_point_is_exact(self):
        rows = study(batch=2, bits=[9])
        assert rows[0]["lossless"]
        assert rows[0]["max_abs_err"] == 0
        assert rows[0]["top1_agreement"] == 1.0

    def test_error_grows_as_resolution_drops(self):
        rows = study(batch=2, bits=[9, 7, 5])
        errs = [r["rel_err"] for r in rows]
        assert errs[0] == 0.0
        assert errs[1] <= errs[2], f"non-monotone: {errs}"
        assert errs[2] > 0.0

    def test_low_resolution_changes_predictions(self):
        rows = study(batch=4, bits=[4])
        assert rows[0]["top1_agreement"] < 1.0 or rows[0]["rel_err"] > 0.05
