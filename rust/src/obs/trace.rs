//! Deterministic Chrome-`trace_event` timeline sink.
//!
//! [`TraceSink`] records typed spans (`ph: "X"`) and instants (`ph: "i"`)
//! stamped in **virtual** microseconds and renders them as a Chrome
//! trace JSON array — load the file in Perfetto (<https://ui.perfetto.dev>)
//! or `chrome://tracing` to see the fleet timeline: one row ("thread
//! lane") per worker plus synthetic lanes for the replication controller,
//! fault windows, and plan-cache activity.
//!
//! Two modes share one byte format:
//!
//! * [`TraceSink::buffered`] keeps events in memory and returns the
//!   rendered JSON from [`TraceSink::finish`] — for tests and small runs;
//! * [`TraceSink::streaming`] opens the output file up front and writes
//!   each event as it is emitted, so a million-request replay holds O(1)
//!   trace memory ([`TraceSink::high_water`] stays 0; the hot-path bench
//!   asserts it).
//!
//! Determinism: nothing here reads the clock or any RNG — timestamps are
//! the simulator's virtual times, floats render shortest-roundtrip, and
//! strings are escaped by [`crate::util::json::escape_into`], which emits
//! exactly what the in-repo parser accepts. Two runs of the same replay
//! produce byte-identical files (`tests/obs_trace.rs` pins this).

use std::collections::BTreeMap;
use std::fs;
use std::io::{self, BufWriter, Write};
use std::path::{Path, PathBuf};

use crate::util::json;

/// One argument value attached to a trace event (`args: {...}`).
#[derive(Debug, Clone, PartialEq)]
pub enum Arg {
    U64(u64),
    F64(f64),
    Str(String),
    Bool(bool),
}

/// One Chrome trace event. `ts`/`dur` are virtual microseconds; `pid` is
/// always 0 (one simulated fleet per file) and `tid` selects the lane.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub name: String,
    /// Category tag (`batch`, `weights`, `fault`, `controller`, `plan`).
    pub cat: &'static str,
    /// `'X'` complete span, `'i'` instant, `'M'` metadata.
    pub ph: char,
    pub ts_us: f64,
    /// Span duration; ignored for instants and metadata.
    pub dur_us: f64,
    pub tid: u64,
    pub args: Vec<(&'static str, Arg)>,
}

impl TraceEvent {
    fn render_into(&self, out: &mut String) {
        use std::fmt::Write as _;
        out.push_str("{\"name\":");
        json::escape_into(out, &self.name);
        let _ = write!(out, ",\"cat\":\"{}\",\"ph\":\"{}\"", self.cat, self.ph);
        let _ = write!(out, ",\"ts\":{}", self.ts_us);
        if self.ph == 'X' {
            let _ = write!(out, ",\"dur\":{}", self.dur_us);
        }
        let _ = write!(out, ",\"pid\":0,\"tid\":{}", self.tid);
        if self.ph == 'i' {
            // Chrome requires a scope on instants; "t" = thread-scoped.
            out.push_str(",\"s\":\"t\"");
        }
        if !self.args.is_empty() {
            out.push_str(",\"args\":{");
            for (i, (k, v)) in self.args.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                json::escape_into(out, k);
                out.push(':');
                match v {
                    Arg::U64(n) => {
                        let _ = write!(out, "{n}");
                    }
                    Arg::F64(x) => {
                        let _ = write!(out, "{x}");
                    }
                    Arg::Str(s) => json::escape_into(out, s),
                    Arg::Bool(b) => {
                        let _ = write!(out, "{b}");
                    }
                }
            }
            out.push('}');
        }
        out.push('}');
    }
}

#[derive(Debug)]
enum Out {
    Buffer(Vec<TraceEvent>),
    Stream {
        w: BufWriter<fs::File>,
        path: PathBuf,
        scratch: String,
    },
}

/// Summary handed back by [`TraceSink::finish`] (and carried on
/// [`SimServeReport`] when a sink was attached).
///
/// [`SimServeReport`]: crate::coordinator::sim_serve::SimServeReport
#[derive(Debug, Clone, PartialEq)]
pub struct TraceDone {
    /// Events emitted over the sink's lifetime.
    pub events: u64,
    /// Maximum simultaneously buffered events (0 in streaming mode — the
    /// memory bound the streaming bench asserts).
    pub high_water: usize,
    /// The rendered JSON document (buffered mode only).
    pub json: Option<String>,
    /// The output file (streaming mode only; closed and flushed).
    pub path: Option<PathBuf>,
}

/// Buffered or streaming trace collector. Emission is infallible —
/// streaming I/O errors are deferred and surfaced by [`finish`]
/// (`io_error` latches), so the hot path never branches on `Result`.
///
/// [`finish`]: TraceSink::finish
#[derive(Debug)]
pub struct TraceSink {
    out: Out,
    events: u64,
    high_water: usize,
    io_error: Option<io::Error>,
}

impl TraceSink {
    /// In-memory sink; [`finish`] renders and returns the JSON document.
    ///
    /// [`finish`]: TraceSink::finish
    pub fn buffered() -> Self {
        TraceSink {
            out: Out::Buffer(Vec::new()),
            events: 0,
            high_water: 0,
            io_error: None,
        }
    }

    /// Streaming sink: opens `path` (creating parent directories) and
    /// writes each event as it is emitted. O(1) memory regardless of
    /// trace length.
    pub fn streaming(path: &Path) -> io::Result<Self> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                fs::create_dir_all(parent)?;
            }
        }
        let mut w = BufWriter::new(fs::File::create(path)?);
        w.write_all(b"[")?;
        Ok(TraceSink {
            out: Out::Stream {
                w,
                path: path.to_path_buf(),
                scratch: String::new(),
            },
            events: 0,
            high_water: 0,
            io_error: None,
        })
    }

    /// Emit a complete span: `[ts_s, ts_s + dur_s)` on lane `tid`.
    pub fn span(
        &mut self,
        name: impl Into<String>,
        cat: &'static str,
        tid: u64,
        ts_s: f64,
        dur_s: f64,
        args: Vec<(&'static str, Arg)>,
    ) {
        self.emit(TraceEvent {
            name: name.into(),
            cat,
            ph: 'X',
            ts_us: ts_s * 1e6,
            dur_us: dur_s * 1e6,
            tid,
            args,
        });
    }

    /// Emit a thread-scoped instant at `ts_s` on lane `tid`.
    pub fn instant(
        &mut self,
        name: impl Into<String>,
        cat: &'static str,
        tid: u64,
        ts_s: f64,
        args: Vec<(&'static str, Arg)>,
    ) {
        self.emit(TraceEvent {
            name: name.into(),
            cat,
            ph: 'i',
            ts_us: ts_s * 1e6,
            dur_us: 0.0,
            tid,
            args,
        });
    }

    /// Name a lane in the viewer (Chrome `thread_name` metadata event).
    pub fn name_lane(&mut self, tid: u64, name: &str) {
        self.emit(TraceEvent {
            name: "thread_name".to_string(),
            cat: "__metadata",
            ph: 'M',
            ts_us: 0.0,
            dur_us: 0.0,
            tid,
            args: vec![("name", Arg::Str(name.to_string()))],
        });
    }

    /// Emit a pre-built event.
    pub fn emit(&mut self, ev: TraceEvent) {
        match &mut self.out {
            Out::Buffer(buf) => {
                buf.push(ev);
                self.high_water = self.high_water.max(buf.len());
            }
            Out::Stream { w, scratch, .. } => {
                scratch.clear();
                if self.events == 0 {
                    scratch.push('\n');
                } else {
                    scratch.push_str(",\n");
                }
                ev.render_into(scratch);
                if self.io_error.is_none() {
                    if let Err(e) = w.write_all(scratch.as_bytes()) {
                        self.io_error = Some(e);
                    }
                }
            }
        }
        self.events += 1;
    }

    /// Events emitted so far.
    pub fn events(&self) -> u64 {
        self.events
    }

    /// Maximum simultaneously buffered events so far (0 while streaming).
    pub fn high_water(&self) -> usize {
        self.high_water
    }

    /// Close the sink: buffered mode renders the JSON document, streaming
    /// mode writes the closing bracket and flushes the file. Any deferred
    /// streaming I/O error surfaces here.
    pub fn finish(self) -> io::Result<TraceDone> {
        let TraceSink {
            out,
            events,
            high_water,
            io_error,
        } = self;
        if let Some(e) = io_error {
            return Err(e);
        }
        match out {
            Out::Buffer(buf) => {
                let mut doc = String::from("[");
                for (i, ev) in buf.iter().enumerate() {
                    doc.push_str(if i == 0 { "\n" } else { ",\n" });
                    ev.render_into(&mut doc);
                }
                doc.push_str("\n]\n");
                Ok(TraceDone {
                    events,
                    high_water,
                    json: Some(doc),
                    path: None,
                })
            }
            Out::Stream { mut w, path, .. } => {
                w.write_all(b"\n]\n")?;
                w.flush()?;
                Ok(TraceDone {
                    events,
                    high_water,
                    json: None,
                    path: Some(path),
                })
            }
        }
    }
}

/// Structural check on a rendered trace document, used by tests and the
/// CLI after writing a file: parses with the in-repo JSON parser and
/// verifies the Chrome `trace_event` array shape (every element an object
/// with `name`/`cat`/`ph`/`ts`/`pid`/`tid`; spans carry `dur`, instants a
/// scope). Returns the number of events.
pub fn validate_chrome_trace(doc: &str) -> Result<usize, String> {
    let parsed = json::parse(doc).map_err(|e| e.to_string())?;
    let arr = parsed.as_arr().ok_or("trace document must be a JSON array")?;
    for (i, ev) in arr.iter().enumerate() {
        let obj = ev
            .as_obj()
            .ok_or_else(|| format!("event {i} is not an object"))?;
        for key in ["name", "cat", "ph", "ts", "pid", "tid"] {
            if !obj.contains_key(key) {
                return Err(format!("event {i} is missing `{key}`"));
            }
        }
        let ph = obj["ph"].as_str().unwrap_or("");
        match ph {
            "X" => {
                if !obj.contains_key("dur") {
                    return Err(format!("span event {i} is missing `dur`"));
                }
            }
            "i" => {
                if !obj.contains_key("s") {
                    return Err(format!("instant event {i} is missing scope `s`"));
                }
            }
            "M" => {}
            other => return Err(format!("event {i} has unknown phase `{other}`")),
        }
        if obj["ts"].as_f64().is_none() {
            return Err(format!("event {i} has a non-numeric `ts`"));
        }
    }
    Ok(arr.len())
}

/// Count events per `(cat, name)` in a rendered document — convenience
/// for shape assertions in tests.
pub fn event_counts(doc: &str) -> Result<BTreeMap<(String, String), usize>, String> {
    let parsed = json::parse(doc).map_err(|e| e.to_string())?;
    let arr = parsed.as_arr().ok_or("trace document must be a JSON array")?;
    let mut counts = BTreeMap::new();
    for ev in arr {
        let cat = ev.get("cat").and_then(|c| c.as_str()).unwrap_or("").to_string();
        let name = ev.get("name").and_then(|n| n.as_str()).unwrap_or("").to_string();
        *counts.entry((cat, name)).or_insert(0) += 1;
    }
    Ok(counts)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(sink: &mut TraceSink) {
        sink.name_lane(0, "worker 0");
        sink.instant("batch_open", "batch", 0, 0.001, vec![("net", Arg::U64(1))]);
        sink.span(
            "exec",
            "batch",
            0,
            0.002,
            0.0105,
            vec![
                ("k", Arg::U64(4)),
                ("reloaded", Arg::Bool(true)),
                ("net", Arg::Str("vgg11".to_string())),
            ],
        );
    }

    #[test]
    fn buffered_renders_a_valid_chrome_trace() {
        let mut sink = TraceSink::buffered();
        sample(&mut sink);
        assert_eq!(sink.events(), 3);
        assert_eq!(sink.high_water(), 3);
        let done = sink.finish().unwrap();
        let doc = done.json.unwrap();
        assert_eq!(validate_chrome_trace(&doc).unwrap(), 3);
        let counts = event_counts(&doc).unwrap();
        assert_eq!(counts[&("batch".to_string(), "exec".to_string())], 1);
    }

    #[test]
    fn streaming_writes_the_same_bytes_as_buffered() {
        let dir = std::env::temp_dir().join("pimflow_trace_sink_test");
        let path = dir.join("t.json");
        let mut stream = TraceSink::streaming(&path).unwrap();
        sample(&mut stream);
        assert_eq!(stream.high_water(), 0, "streaming never buffers");
        let done = stream.finish().unwrap();
        assert_eq!(done.path.as_deref(), Some(path.as_path()));
        let streamed = std::fs::read_to_string(&path).unwrap();

        let mut buf = TraceSink::buffered();
        sample(&mut buf);
        let buffered = buf.finish().unwrap().json.unwrap();
        assert_eq!(streamed, buffered);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn empty_sink_renders_an_empty_array() {
        let doc = TraceSink::buffered().finish().unwrap().json.unwrap();
        assert_eq!(validate_chrome_trace(&doc).unwrap(), 0);
    }

    #[test]
    fn validator_rejects_malformed_shapes() {
        assert!(validate_chrome_trace("{}").is_err(), "not an array");
        assert!(
            validate_chrome_trace(r#"[{"name":"x"}]"#).is_err(),
            "missing required keys"
        );
        assert!(
            validate_chrome_trace(
                r#"[{"name":"x","cat":"c","ph":"X","ts":1,"pid":0,"tid":0}]"#
            )
            .is_err(),
            "span without dur"
        );
        assert!(validate_chrome_trace("[").is_err(), "parse error");
    }

    #[test]
    fn timestamps_render_shortest_roundtrip() {
        let mut sink = TraceSink::buffered();
        sink.instant("t", "batch", 7, 0.25, vec![]);
        let doc = sink.finish().unwrap().json.unwrap();
        assert!(doc.contains("\"ts\":250000"), "0.25 s is 250000 µs: {doc}");
        assert!(doc.contains("\"tid\":7"));
    }
}
