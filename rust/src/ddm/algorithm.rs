//! Algorithm 1 — the Dynamic Duplication Method (DDM).
//!
//! Faithful implementation of the paper's pseudo-code: per part, while
//! extra tiles `E` remain (at least the smallest unit's footprint), pick
//! the bottleneck layer via the ITP and grant it one more copy, except
//! that FC layers are never duplicated (`dupNum=1`) and each layer is
//! capped at `MAX[i]` (∝ O²) copies. `Flag` becomes a per-unit skip set so
//! un-duplicable bottlenecks don't livelock the loop.

use crate::mapping::duplication::{extra_tiles, max_dup, next_copy_cost};
use crate::partition::{Part, PartitionPlan};
use crate::pim::ChipModel;

use super::itp;

/// Duplication factors chosen for one part (parallel to `part.units`).
pub type PartDups = Vec<u32>;

/// Result of running Algorithm 1 over a whole partition plan.
#[derive(Debug, Clone)]
pub struct DdmResult {
    /// `dup_per_part[p][i]` = dupNum of unit `i` in part `p`.
    pub dup_per_part: Vec<PartDups>,
}

impl DdmResult {
    /// All-ones result (DDM disabled).
    pub fn disabled(plan: &PartitionPlan) -> Self {
        DdmResult {
            dup_per_part: plan.parts.iter().map(|p| vec![1; p.units.len()]).collect(),
        }
    }

    /// Total extra tile-copies granted (diagnostic).
    pub fn total_extra_copies(&self) -> u64 {
        self.dup_per_part
            .iter()
            .flatten()
            .map(|&d| (d.saturating_sub(1)) as u64)
            .sum()
    }
}

/// Run Algorithm 1 on one part.
pub fn ddm_part(part: &Part, chip: &ChipModel) -> PartDups {
    let n = part.units.len();
    let mut dups: PartDups = vec![1; n];
    if n == 0 {
        return dups;
    }
    // line 3: minimum tile footprint among this part's layers
    let min_tile = part.units.iter().map(|u| u.tiles).min().unwrap_or(1).max(1);
    // Flag bookkeeping: units proven un-duplicable are skipped thereafter.
    let mut skip = vec![false; n];

    // line 4: while E >= min_tile (plus: stop when every unit is skipped)
    loop {
        let e = extra_tiles(part, chip, &dups);
        if e < min_tile {
            break;
        }
        // line 5: update ITP, select bottleneck layer l
        let Some(l) = itp::bottleneck(chip, &part.units, &dups, &skip) else {
            break; // all layers skipped
        };
        let unit = &part.units[l];
        // line 6: enough extra tiles for one more copy of l?
        if e >= next_copy_cost(unit) {
            if unit.is_fc {
                // lines 8-9: FC layers are never duplicated
                dups[l] = 1;
                skip[l] = true;
            } else if dups[l] + 1 > max_dup(chip, unit) {
                // lines 10-11: cap at MAX[l]
                skip[l] = true;
            } else {
                // line 7: grant the copy
                dups[l] += 1;
            }
        } else {
            // line 13-14: bottleneck unaffordable — skip it and let the
            // search consider the next-slowest layer.
            skip[l] = true;
        }
    }
    dups
}

/// Work counters for one [`run_with_stats`] pass over a plan.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DdmRunStats {
    /// Parts that went through the full Algorithm-1 loop.
    pub evaluated: u64,
    /// Singleton parts answered by the closed-form early-out.
    pub singleton_skips: u64,
}

/// A singleton part whose unit provably keeps `dup = 1`, so the greedy
/// loop would return `[1]` without granting anything: the unit is FC
/// (lines 8-9), already at `MAX[l] = 1` (lines 10-11), or there is no
/// room for a second copy (`E < N_tile`, the line-4 guard — for a
/// singleton `min_tile` *is* the unit's footprint).
fn singleton_pinned(part: &Part, chip: &ChipModel) -> bool {
    let [u] = part.units.as_slice() else {
        return false;
    };
    u.tiles >= 1
        && (u.is_fc
            || max_dup(chip, u) <= 1
            || extra_tiles(part, chip, &[1]) < next_copy_cost(u))
}

/// [`run`] with work counters: singleton parts already at their
/// duplication bound skip the loop entirely. The result is bitwise
/// identical to evaluating every part (pinned by the inline tests and
/// `tests/exact_oracle.rs`).
pub fn run_with_stats(plan: &PartitionPlan, chip: &ChipModel) -> (DdmResult, DdmRunStats) {
    let mut stats = DdmRunStats::default();
    let dup_per_part = plan
        .parts
        .iter()
        .map(|p| {
            if singleton_pinned(p, chip) {
                stats.singleton_skips += 1;
                vec![1]
            } else {
                stats.evaluated += 1;
                ddm_part(p, chip)
            }
        })
        .collect();
    (DdmResult { dup_per_part }, stats)
}

/// Run Algorithm 1 over every part of the plan.
pub fn run(plan: &PartitionPlan, chip: &ChipModel) -> DdmResult {
    run_with_stats(plan, chip).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cfg::presets;
    use crate::ddm::itp::part_interval_ns;
    use crate::mapping::duplication::tiles_with_dups;
    use crate::nn::resnet;
    use crate::partition::partition;
    use crate::pim::ChipModel;

    fn setup(net: &str) -> (ChipModel, crate::partition::PartitionPlan) {
        let chip = ChipModel::new(presets::compact_rram_41mm2()).unwrap();
        let plan = partition(&resnet::by_name(net, 100).unwrap(), &chip).unwrap();
        (chip, plan)
    }

    #[test]
    fn result_always_fits_chip() {
        for net in ["resnet18", "resnet34", "resnet50"] {
            let (chip, plan) = setup(net);
            let res = run(&plan, &chip);
            for (part, dups) in plan.parts.iter().zip(&res.dup_per_part) {
                assert!(
                    tiles_with_dups(part, dups) <= chip.num_tiles(),
                    "{net} overflows"
                );
            }
        }
    }

    #[test]
    fn never_worse_than_no_ddm() {
        let (chip, plan) = setup("resnet34");
        let res = run(&plan, &chip);
        for (part, dups) in plan.parts.iter().zip(&res.dup_per_part) {
            let base = part_interval_ns(&chip, &part.units, &vec![1; part.units.len()]);
            let tuned = part_interval_ns(&chip, &part.units, dups);
            assert!(tuned <= base + 1e-9);
        }
    }

    #[test]
    fn ddm_improves_some_part() {
        // The whole point: at least one part must get faster.
        let (chip, plan) = setup("resnet34");
        let res = run(&plan, &chip);
        let improved = plan.parts.iter().zip(&res.dup_per_part).any(|(part, dups)| {
            let base = part_interval_ns(&chip, &part.units, &vec![1; part.units.len()]);
            let tuned = part_interval_ns(&chip, &part.units, dups);
            tuned < base * 0.75
        });
        assert!(improved, "DDM produced no meaningful speedup on any part");
    }

    #[test]
    fn fc_layers_never_duplicated() {
        for net in ["resnet18", "resnet34", "resnet50"] {
            let (chip, plan) = setup(net);
            let res = run(&plan, &chip);
            for (part, dups) in plan.parts.iter().zip(&res.dup_per_part) {
                for (u, &d) in part.units.iter().zip(dups) {
                    if u.is_fc {
                        assert_eq!(d, 1, "{net}: FC duplicated");
                    }
                }
            }
        }
    }

    #[test]
    fn caps_respected() {
        let (chip, plan) = setup("resnet34");
        let res = run(&plan, &chip);
        for (part, dups) in plan.parts.iter().zip(&res.dup_per_part) {
            for (u, &d) in part.units.iter().zip(dups) {
                assert!(d >= 1 && d <= chip.max_dup(&u.layer));
            }
        }
    }

    #[test]
    fn disabled_is_all_ones() {
        let (_, plan) = setup("resnet18");
        let res = DdmResult::disabled(&plan);
        assert!(res.dup_per_part.iter().flatten().all(|&d| d == 1));
        assert_eq!(res.total_extra_copies(), 0);
    }

    #[test]
    fn deterministic() {
        let (chip, plan) = setup("resnet50");
        let a = run(&plan, &chip);
        let b = run(&plan, &chip);
        assert_eq!(a.dup_per_part, b.dup_per_part);
    }

    /// Reference `run` without the singleton early-out (the pre-fix
    /// behaviour): every part goes through the full greedy loop.
    fn run_all_parts(plan: &crate::partition::PartitionPlan, chip: &ChipModel) -> DdmResult {
        DdmResult {
            dup_per_part: plan.parts.iter().map(|p| ddm_part(p, chip)).collect(),
        }
    }

    #[test]
    fn singleton_early_out_is_bitwise_identical() {
        for net in ["resnet18", "resnet34", "resnet50", "resnet101", "resnet152"] {
            let (chip, plan) = setup(net);
            let (fast, stats) = run_with_stats(&plan, &chip);
            let reference = run_all_parts(&plan, &chip);
            assert_eq!(fast.dup_per_part, reference.dup_per_part, "{net}");
            assert_eq!(
                stats.evaluated + stats.singleton_skips,
                plan.num_parts() as u64,
                "{net}: every part accounted for"
            );
        }
    }

    #[test]
    fn singleton_early_out_counts_skips() {
        // Force pinned singletons: one FC-only part and one part whose
        // unit fills the whole chip (no room for a second copy).
        let (chip, plan) = setup("resnet34");
        let fc_unit = plan
            .parts
            .iter()
            .flat_map(|p| &p.units)
            .find(|u| u.is_fc)
            .expect("resnet34 has an FC head")
            .clone();
        let mut big_unit = plan.parts[0].units[0].clone();
        big_unit.tiles = chip.num_tiles(); // fills the chip exactly
        let open_unit = plan.parts[0].units[0].clone(); // has idle room
        assert!(open_unit.tiles * 2 <= chip.num_tiles());
        let synthetic = crate::partition::PartitionPlan {
            parts: vec![
                Part { units: vec![fc_unit] },
                Part { units: vec![big_unit] },
                Part { units: vec![open_unit] },
            ],
            network: "synthetic".into(),
        };
        let (res, stats) = run_with_stats(&synthetic, &chip);
        assert_eq!(stats.singleton_skips, 2, "FC + chip-filling singletons");
        assert_eq!(stats.evaluated, 1, "the open singleton still runs");
        assert_eq!(res.dup_per_part, run_all_parts(&synthetic, &chip).dup_per_part);
        assert_eq!(res.dup_per_part[0], vec![1]);
        assert_eq!(res.dup_per_part[1], vec![1]);
        assert!(res.dup_per_part[2][0] > 1, "open singleton must duplicate");
    }
}
