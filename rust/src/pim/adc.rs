//! Column-ADC model: resolution requirements and energy/area scaling.
//!
//! Each crossbar read converts per-column analog partial sums. ADC energy
//! scales roughly 4× per extra 2 bits (class-B SAR scaling), and the
//! lossless resolution follows from the worst-case column sum — mirroring
//! the L1 Pallas kernel's `lossless_adc_bits`.

/// Minimum ADC bits so a partial sum of `rows` cells at `cell_bits` each
/// never saturates (matches `python/compile/kernels/crossbar.py`).
pub fn lossless_bits(cell_bits: u32, rows: u32) -> u32 {
    let max_partial = rows as u64 * ((1u64 << cell_bits) - 1);
    let mut bits = 1;
    while (1u64 << bits) - 1 < max_partial {
        bits += 1;
    }
    bits
}

/// Relative ADC energy vs an 8-bit reference converter (SAR ~4×/2bits).
pub fn energy_scale(bits: u32) -> f64 {
    2f64.powi(bits as i32 - 8)
}

/// Relative ADC area vs the 8-bit reference.
pub fn area_scale(bits: u32) -> f64 {
    2f64.powi(bits as i32 - 8)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_kernel_constant() {
        // 2 bit/cell, 128 rows: max partial 384 -> 9 bits (kernel default).
        assert_eq!(lossless_bits(2, 128), 9);
        assert_eq!(lossless_bits(1, 128), 8);
        assert_eq!(lossless_bits(4, 128), 11);
    }

    #[test]
    fn monotone_in_rows_and_bits() {
        assert!(lossless_bits(2, 256) > lossless_bits(2, 64));
        assert!(lossless_bits(4, 128) > lossless_bits(1, 128));
    }

    #[test]
    fn scaling_reference_point() {
        assert!((energy_scale(8) - 1.0).abs() < 1e-12);
        assert!((energy_scale(10) - 4.0).abs() < 1e-12);
        assert!((area_scale(6) - 0.25).abs() < 1e-12);
    }
}
