"""Layer-2 JAX model: fully-integer quantized CNN built on the crossbar kernel.

This is the functional twin of the workload the Rust simulator schedules:
8-bit weights / 8-bit unsigned activations / int32 accumulation, convolution
as im2col + crossbar matmul (the paper maps CONV/FC onto crossbar subarrays
the same way), rounded-right-shift requantization between layers.

Everything here is build-time: ``aot.py`` lowers the jitted forwards to HLO
text once, and the Rust runtime executes the artifacts; Python never sits on
the request path.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.crossbar import crossbar_matmul

__all__ = [
    "CrossbarOpts",
    "QConv",
    "QLinear",
    "im2col",
    "conv2d_q",
    "requantize",
    "avg_pool_q",
    "linear_q",
    "QBlock",
    "basic_block_q",
    "init_tiny_cnn_params",
    "tiny_cnn_forward",
    "init_block_params",
    "resnet_block_forward",
    "tiny_cnn_param_count",
    "tiny_cnn_macs",
]

ACT_MAX = 255  # u8 activations


@dataclasses.dataclass(frozen=True)
class CrossbarOpts:
    """Crossbar configuration threaded through every conv/fc call."""

    cell_bits: int = 2
    adc_bits: int = 9
    subarray_rows: int = 128
    # §Perf: large M-blocks amortize interpret-mode grid overhead; the
    # VMEM-resident stripe (block_m × K × 4 B ≤ 4.7 MB for the largest K)
    # stays inside a 16 MB budget. Swept in EXPERIMENTS.md §Perf.
    block_m: int = 1024
    block_n: int = 32
    interpret: bool = True

    def matmul(self, x: jax.Array, w: jax.Array) -> jax.Array:
        return crossbar_matmul(
            x,
            w,
            cell_bits=self.cell_bits,
            adc_bits=self.adc_bits,
            subarray_rows=self.subarray_rows,
            block_m=self.block_m,
            block_n=self.block_n,
            interpret=self.interpret,
        )


@dataclasses.dataclass(frozen=True)
class QConv:
    """Quantized conv parameters: HWIO int8 weights + requant shift."""

    w: jax.Array  # (kh, kw, cin, cout) int32 holding int8 values
    shift: int
    stride: int = 1
    pad: int = 1


@dataclasses.dataclass(frozen=True)
class QLinear:
    w: jax.Array  # (cin, cout) int32 holding int8 values


def im2col(x: jax.Array, kh: int, kw: int, stride: int, pad: int) -> jax.Array:
    """(B, H, W, C) -> (B*OH*OW, kh*kw*C) patch matrix.

    Column ordering is (i, j, channel) row-major over the filter window,
    matching ``w.reshape(kh*kw*cin, cout)`` for HWIO weights.
    """
    b, h, w_, c = x.shape
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (w_ + 2 * pad - kw) // stride + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            patch = jax.lax.slice(
                xp,
                (0, i, j, 0),
                (b, i + (oh - 1) * stride + 1, j + (ow - 1) * stride + 1, c),
                (1, stride, stride, 1),
            )
            cols.append(patch)
    stacked = jnp.concatenate(cols, axis=-1)  # (B, OH, OW, kh*kw*C)
    return stacked.reshape(b * oh * ow, kh * kw * c)


def requantize(acc: jax.Array, shift: int, *, relu: bool = True) -> jax.Array:
    """int32 accumulator -> u8 activation via rounded right shift.

    ReLU is implicit in the lower clip at 0 (the hardware's unsigned
    activation datapath); ``relu=False`` keeps a symmetric signed clip for
    residual taps that feed an addition rather than the next layer.
    """
    rounded = (acc + (1 << (shift - 1))) >> shift
    if relu:
        return jnp.clip(rounded, 0, ACT_MAX)
    return jnp.clip(rounded, -(ACT_MAX + 1) // 2, ACT_MAX // 2)


def conv2d_q(
    x: jax.Array,
    conv: QConv,
    opts: CrossbarOpts,
    *,
    requant: bool = True,
) -> jax.Array:
    """Quantized 3x3/1x1 convolution on the crossbar: im2col + matmul.

    ``x``: (B, H, W, Cin) int32 u8-range. Returns (B, OH, OW, Cout) int32,
    requantized to u8 range unless ``requant=False`` (raw accumulators).
    """
    kh, kw, cin, cout = conv.w.shape
    b, h, w_, _ = x.shape
    oh = (h + 2 * conv.pad - kh) // conv.stride + 1
    ow = (w_ + 2 * conv.pad - kw) // conv.stride + 1

    patches = im2col(x, kh, kw, conv.stride, conv.pad)
    wmat = conv.w.reshape(kh * kw * cin, cout)
    acc = opts.matmul(patches, wmat)
    acc = acc.reshape(b, oh, ow, cout)
    if requant:
        return requantize(acc, conv.shift)
    return acc


def avg_pool_q(x: jax.Array) -> jax.Array:
    """Global average pool (B, H, W, C) -> (B, C), integer floor division."""
    b, h, w_, c = x.shape
    return jnp.sum(x, axis=(1, 2)) // (h * w_)


def linear_q(x: jax.Array, lin: QLinear, opts: CrossbarOpts) -> jax.Array:
    """FC layer on the crossbar; returns raw int32 logits (no requant)."""
    return opts.matmul(x, lin.w)


@dataclasses.dataclass(frozen=True)
class QBlock:
    """BasicBlock parameters: two convs, optional 1x1 downsample projection,
    and the left-shift applied to the identity skip so it joins the raw
    accumulator at a matched scale."""

    conv_a: QConv
    conv_b: QConv
    down: QConv | None = None
    skip_bits: int = 0


def basic_block_q(x: jax.Array, block: QBlock, opts: CrossbarOpts) -> jax.Array:
    """ResNet BasicBlock: conv-conv + identity/1x1-projected skip, int32 adds.

    The skip join happens on raw accumulators (pre-requant), mirroring the
    chip's digital accumulation stage, then one requantization emits u8.
    """
    y = conv2d_q(x, block.conv_a, opts)
    acc = conv2d_q(y, block.conv_b, opts, requant=False)
    if block.down is not None:
        skip = conv2d_q(x, block.down, opts, requant=False)
    else:
        skip = x << block.skip_bits
    return requantize(acc + skip, block.conv_b.shift)


# ---------------------------------------------------------------------------
# Tiny CIFAR-100 CNN (the e2e serving artifact)
# ---------------------------------------------------------------------------

TINY_CNN_STAGES: Sequence[Tuple[int, int, int]] = (
    # (cin, cout, stride) for the three basic blocks after the stem.
    (16, 16, 1),
    (16, 32, 2),
    (32, 64, 2),
)
TINY_CNN_CLASSES = 100


def _rand_w(rng: np.random.Generator, shape: Tuple[int, ...]) -> jax.Array:
    """Synthetic int8 weights (paper evaluates system metrics, not accuracy)."""
    return jnp.asarray(rng.integers(-128, 128, shape, dtype=np.int32))


# --- numpy calibration helpers (build-time only) ---------------------------


def _np_im2col(x: np.ndarray, kh: int, kw: int, stride: int, pad: int) -> np.ndarray:
    b, h, w_, c = x.shape
    xp = np.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (w_ + 2 * pad - kw) // stride + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            cols.append(
                xp[:, i : i + (oh - 1) * stride + 1 : stride, j : j + (ow - 1) * stride + 1 : stride, :]
            )
    return np.concatenate(cols, axis=-1).reshape(b * oh * ow, kh * kw * c), oh, ow


def _np_conv_acc(x: np.ndarray, w: np.ndarray, stride: int, pad: int) -> np.ndarray:
    kh, kw, cin, cout = w.shape
    patches, oh, ow = _np_im2col(x, kh, kw, stride, pad)
    acc = patches.astype(np.int64) @ w.reshape(kh * kw * cin, cout).astype(np.int64)
    return acc.reshape(x.shape[0], oh, ow, cout)


def _pick_shift(acc: np.ndarray, target: int = 200) -> int:
    """Shift such that the 99.9th percentile of |acc| lands near ``target``."""
    hi = float(np.percentile(np.abs(acc), 99.9))
    shift = 1
    while (hi / (1 << shift)) > target and shift < 31:
        shift += 1
    return shift


def _np_requant(acc: np.ndarray, shift: int) -> np.ndarray:
    return np.clip((acc + (1 << (shift - 1))) >> shift, 0, ACT_MAX)


def init_tiny_cnn_params(seed: int = 0) -> Dict[str, object]:
    """Synthetic int8 parameters with percentile-calibrated requant shifts.

    The calibration pass walks the network once in numpy on a random probe
    batch and picks each layer's right-shift so post-requant activations
    occupy the u8 range instead of saturating or dying — the build-time
    analogue of post-training-quantization range calibration.
    """
    rng = np.random.default_rng(seed)
    probe = rng.integers(0, 256, (2, 32, 32, 3), dtype=np.int64)

    w_stem = rng.integers(-128, 128, (3, 3, 3, 16), dtype=np.int64)
    acc = _np_conv_acc(probe, w_stem, 1, 1)
    s_stem = _pick_shift(acc)
    y = _np_requant(acc, s_stem)
    params: Dict[str, object] = {
        "stem": QConv(jnp.asarray(w_stem, jnp.int32), shift=s_stem)
    }

    for idx, (cin, cout, stride) in enumerate(TINY_CNN_STAGES):
        w_a = rng.integers(-128, 128, (3, 3, cin, cout), dtype=np.int64)
        w_b = rng.integers(-128, 128, (3, 3, cout, cout), dtype=np.int64)
        w_d = (
            rng.integers(-128, 128, (1, 1, cin, cout), dtype=np.int64)
            if (stride != 1 or cin != cout)
            else None
        )

        acc_a = _np_conv_acc(y, w_a, stride, 1)
        s_a = _pick_shift(acc_a)
        y_a = _np_requant(acc_a, s_a)

        acc_b = _np_conv_acc(y_a, w_b, 1, 1)
        s_b_pre = _pick_shift(acc_b)
        skip_bits = max(0, s_b_pre - 1)
        if w_d is not None:
            skip = _np_conv_acc(y, w_d, stride, 0)
        else:
            skip = y.astype(np.int64) << skip_bits
        joint = acc_b + skip
        s_b = _pick_shift(joint)
        y = _np_requant(joint, s_b)

        down = None
        if w_d is not None:
            down = QConv(jnp.asarray(w_d, jnp.int32), shift=s_b, stride=stride, pad=0)
        params[f"block{idx}"] = QBlock(
            conv_a=QConv(jnp.asarray(w_a, jnp.int32), shift=s_a, stride=stride),
            conv_b=QConv(jnp.asarray(w_b, jnp.int32), shift=s_b),
            down=down,
            skip_bits=skip_bits,
        )

    params["fc"] = QLinear(_rand_w(rng, (64, TINY_CNN_CLASSES)))
    return params


def tiny_cnn_forward(
    x: jax.Array, params: Dict[str, object], opts: CrossbarOpts | None = None
) -> jax.Array:
    """(B, 32, 32, 3) u8-range int32 image -> (B, 100) int32 logits."""
    opts = opts or CrossbarOpts()
    y = conv2d_q(x, params["stem"], opts)
    for idx in range(len(TINY_CNN_STAGES)):
        y = basic_block_q(y, params[f"block{idx}"], opts)
    pooled = avg_pool_q(y)
    return linear_q(pooled, params["fc"], opts)


def tiny_cnn_param_count() -> int:
    n = 3 * 3 * 3 * 16
    for cin, cout, stride in TINY_CNN_STAGES:
        n += 3 * 3 * cin * cout + 3 * 3 * cout * cout
        if stride != 1 or cin != cout:
            n += cin * cout
    return n + 64 * TINY_CNN_CLASSES


def tiny_cnn_macs(batch: int = 1) -> int:
    """MAC count of one forward pass (for throughput accounting)."""
    macs = 32 * 32 * 3 * 3 * 3 * 16  # stem
    hw = 32
    for cin, cout, stride in TINY_CNN_STAGES:
        hw_out = hw // stride
        macs += hw_out * hw_out * 3 * 3 * cin * cout
        macs += hw_out * hw_out * 3 * 3 * cout * cout
        if stride != 1 or cin != cout:
            macs += hw_out * hw_out * cin * cout
        hw = hw_out
    macs += 64 * TINY_CNN_CLASSES
    return macs * batch


# ---------------------------------------------------------------------------
# Standalone ResNet basic block artifact (mid-size compile unit)
# ---------------------------------------------------------------------------


def init_block_params(cin: int = 32, cout: int = 32, seed: int = 1) -> QBlock:
    """Calibrated standalone BasicBlock (mid-size AOT compile unit)."""
    rng = np.random.default_rng(seed)
    probe = rng.integers(0, 200, (2, 8, 8, cin), dtype=np.int64)
    w_a = rng.integers(-128, 128, (3, 3, cin, cout), dtype=np.int64)
    w_b = rng.integers(-128, 128, (3, 3, cout, cout), dtype=np.int64)

    acc_a = _np_conv_acc(probe, w_a, 1, 1)
    s_a = _pick_shift(acc_a)
    y_a = _np_requant(acc_a, s_a)
    acc_b = _np_conv_acc(y_a, w_b, 1, 1)
    s_b_pre = _pick_shift(acc_b)
    skip_bits = max(0, s_b_pre - 1)
    joint = acc_b + (probe << skip_bits)
    s_b = _pick_shift(joint)

    return QBlock(
        conv_a=QConv(jnp.asarray(w_a, jnp.int32), shift=s_a),
        conv_b=QConv(jnp.asarray(w_b, jnp.int32), shift=s_b),
        down=None,
        skip_bits=skip_bits,
    )


def resnet_block_forward(
    x: jax.Array, params: QBlock, opts: CrossbarOpts | None = None
) -> jax.Array:
    opts = opts or CrossbarOpts()
    return basic_block_q(x, params, opts)
