//! Virtual-worker state for the simulated serving fleet.
//!
//! A [`VWorker`] is one simulated execution slot: it remembers when it
//! drains (`busy_until_s`), which network's weights it currently holds
//! (`loaded`), its single open batch, and its own reload/pre-warm/
//! utilization accounting. The fleet-level scheduler ([`SimServer`]) owns
//! the pricing (cached-plan makespans, reload penalties), consults a
//! [`Placement`] policy to pick which worker a request rides, and mirrors
//! every `loaded` change into the fleet's [`ReplicaSet`]; the worker
//! itself is pure state, so the accepted-never-misses-SLO argument stays
//! per-worker: only this worker's own open batch can execute on it
//! between a quote and the quoted batch, exactly as in the single-worker
//! model. (The replication controller may also stream weights onto a
//! worker — a pre-warm — but only when it has **no open batch**, so no
//! issued quote is ever invalidated.)
//!
//! [`SimServer`]: crate::coordinator::sim_serve::SimServer
//! [`Placement`]: crate::coordinator::placement::Placement
//! [`ReplicaSet`]: crate::coordinator::replica::ReplicaSet

use crate::util::LatencyHist;

/// One not-yet-executed batch on a worker. At most one per worker.
#[derive(Debug, Clone)]
pub struct OpenBatch {
    /// Network index (into the server's network slice).
    pub net: usize,
    /// Arrival of the batch's first member — the binding SLO check.
    pub first_arrival_s: f64,
    /// Worst-case close time: `first_arrival_s + max_wait_s`. Quotes use
    /// it; an earlier close (full batch / fresh opener) only helps.
    pub deadline_s: f64,
    /// `(request id, arrival_s)` per member.
    pub members: Vec<(u64, f64)>,
}

/// End-of-trace counters for one worker (reported next to the per-network
/// rows; `utilization` is busy time over the *fleet* span).
#[derive(Debug, Clone, Default)]
pub struct WorkerStats {
    pub id: usize,
    pub batches: u64,
    pub completed: u64,
    /// Batches that had to stream weights because a different network (or
    /// none) was loaded on this worker when they executed.
    pub reloads: u64,
    /// Weight streams the replica controller charged to this worker ahead
    /// of demand (same cost as a reload, off the batch critical path).
    pub prewarms: u64,
    /// Seconds spent executing (reload + pre-warm + pipeline), excluding
    /// idle gaps.
    pub busy_s: f64,
    /// When this worker went idle after its last batch.
    pub idle_at_s: f64,
    /// Network resident at end of trace, if any.
    pub resident: Option<usize>,
    /// Fault-plan crashes applied to this worker during the trace.
    pub crashes: u64,
    /// Total scheduled downtime from those crashes, seconds (not counted
    /// as busy time — a down worker is unavailable, not utilized).
    pub down_s: f64,
    /// Log-scale latency histogram of the completions this worker served
    /// (p50/p99/p999 per worker in the fleet table).
    pub hist: LatencyHist,
}

impl WorkerStats {
    /// Busy fraction of the fleet's virtual span.
    pub fn utilization(&self, fleet_span_s: f64) -> f64 {
        if fleet_span_s <= 0.0 {
            0.0
        } else {
            self.busy_s / fleet_span_s
        }
    }

    /// Register this worker's counters under `worker.<id>.*`.
    pub fn register(&self, reg: &mut crate::obs::Registry) {
        let p = |k: &str| format!("worker.{}.{k}", self.id);
        reg.counter(p("batches_total"), self.batches);
        reg.counter(p("completed_total"), self.completed);
        reg.counter(p("reloads_total"), self.reloads);
        reg.counter(p("prewarms_total"), self.prewarms);
        reg.counter(p("crashes_total"), self.crashes);
        reg.gauge(p("busy_s"), self.busy_s);
        reg.gauge(p("down_s"), self.down_s);
        reg.gauge(p("idle_at_s"), self.idle_at_s);
        reg.hist(&p("latency"), &self.hist);
    }
}

/// One virtual worker: FIFO over its own batches, one open batch at a
/// time, weights stay loaded until a different network executes (or the
/// replica controller pre-warms/drains them).
#[derive(Debug)]
pub struct VWorker {
    pub id: usize,
    /// When the worker drains everything already executed on it.
    pub busy_until_s: f64,
    /// Network whose weights are resident (None before the first batch).
    pub loaded: Option<usize>,
    /// The worker's single open (not yet executed) batch.
    pub open: Option<OpenBatch>,
    pub batches: u64,
    pub completed: u64,
    pub reloads: u64,
    pub prewarms: u64,
    pub busy_s: f64,
    /// Fault-plan crashes applied to this worker (see `coordinator::chaos`).
    pub crashes: u64,
    /// Total scheduled downtime from those crashes, seconds.
    pub down_s: f64,
    /// Latencies of the completions this worker served.
    pub hist: LatencyHist,
}

impl VWorker {
    pub fn new(id: usize) -> Self {
        VWorker {
            id,
            busy_until_s: 0.0,
            loaded: None,
            open: None,
            batches: 0,
            completed: 0,
            reloads: 0,
            prewarms: 0,
            busy_s: 0.0,
            crashes: 0,
            down_s: 0.0,
            hist: LatencyHist::new(),
        }
    }

    /// Members in the open batch (0 when none is open).
    pub fn open_members(&self) -> usize {
        self.open.as_ref().map_or(0, |b| b.members.len())
    }

    /// Network of the open batch, if one is open.
    pub fn open_net(&self) -> Option<usize> {
        self.open.as_ref().map(|b| b.net)
    }

    /// Whether routing a request for `net` here avoids a weight reload:
    /// the weights are resident, or the open batch (which will load them)
    /// is for the same network. This is the single-worker view; placement
    /// evaluates the same predicate through the fleet's `ReplicaSet`
    /// (`is_holder(w, net) || open_net() == Some(net)`), which the
    /// simulator keeps in exact mirror with `loaded` — the equivalence is
    /// what `tests/replica_props.rs` conserves.
    pub fn holds(&self, net: usize) -> bool {
        self.loaded == Some(net) || self.open_net() == Some(net)
    }

    /// Snapshot the end-of-trace counters.
    pub fn stats(&self) -> WorkerStats {
        WorkerStats {
            id: self.id,
            batches: self.batches,
            completed: self.completed,
            reloads: self.reloads,
            prewarms: self.prewarms,
            busy_s: self.busy_s,
            idle_at_s: self.busy_until_s,
            resident: self.loaded,
            crashes: self.crashes,
            down_s: self.down_s,
            hist: self.hist.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_workers_are_idle_and_hold_nothing() {
        let w = VWorker::new(3);
        assert_eq!(w.id, 3);
        assert_eq!(w.busy_until_s, 0.0);
        assert_eq!(w.open_members(), 0);
        assert_eq!(w.open_net(), None);
        assert!(!w.holds(0));
        let s = w.stats();
        assert_eq!((s.batches, s.reloads, s.completed, s.prewarms), (0, 0, 0, 0));
        assert_eq!((s.crashes, s.down_s), (0, 0.0));
        assert_eq!(s.resident, None);
        assert_eq!(s.utilization(1.0), 0.0);
    }

    #[test]
    fn holds_covers_loaded_weights_and_the_open_batch() {
        let mut w = VWorker::new(0);
        w.loaded = Some(2);
        assert!(w.holds(2));
        assert!(!w.holds(1));
        w.open = Some(OpenBatch {
            net: 1,
            first_arrival_s: 0.0,
            deadline_s: 0.001,
            members: vec![(7, 0.0)],
        });
        assert!(w.holds(1), "the open batch will load net 1's weights");
        assert!(w.holds(2), "net 2 is still resident until a flush");
        assert_eq!(w.open_net(), Some(1));
        assert_eq!(w.open_members(), 1);
        assert_eq!(w.stats().resident, Some(2));
    }

    #[test]
    fn utilization_is_busy_over_fleet_span() {
        let s = WorkerStats {
            busy_s: 0.25,
            ..WorkerStats::default()
        };
        assert_eq!(s.utilization(1.0), 0.25);
        assert_eq!(s.utilization(0.0), 0.0);
    }
}
