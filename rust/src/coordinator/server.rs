//! Server façade: spawn workers, accept requests, expose stats.
//!
//! This is the L3 serving path end to end: `submit()` → queue → dynamic
//! batcher → PJRT executor (AOT artifact) → reply channel. Python is never
//! involved.

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::runtime::ExecutorPool;
use crate::util::stats::Summary;

use super::batcher::BatchPolicy;
use super::request::{validate_image, InferRequest, InferResponse};
use super::worker::{run_worker, Job, ServeStats};

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub workers: usize,
    pub policy: BatchPolicy,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            workers: 1,
            policy: BatchPolicy::default(),
        }
    }
}

/// Latency/throughput snapshot.
#[derive(Debug, Clone)]
pub struct StatsSnapshot {
    pub served: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub latency: Summary,
    pub exec: Summary,
}

/// The serving coordinator.
pub struct Server {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<u64>>,
    stats: Arc<Mutex<ServeStats>>,
    next_id: AtomicU64,
    started: Instant,
}

impl Server {
    /// Compile artifacts and start `cfg.workers` worker threads.
    pub fn start(artifacts_dir: &Path, cfg: ServerConfig) -> Result<Server> {
        anyhow::ensure!(cfg.workers >= 1, "need at least one worker");
        let (tx, rx) = mpsc::channel::<Job>();
        let queue = Arc::new(Mutex::new(rx));
        let stats = Arc::new(Mutex::new(ServeStats::default()));
        let mut workers = Vec::with_capacity(cfg.workers);
        for w in 0..cfg.workers {
            // The xla handles are not Send, so each worker thread builds
            // its own PJRT client + compiled executables; a handshake
            // channel reports compile success before start() returns.
            let queue = Arc::clone(&queue);
            let stats = Arc::clone(&stats);
            let policy = cfg.policy;
            let dir = artifacts_dir.to_path_buf();
            let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
            let handle = std::thread::Builder::new()
                .name(format!("pimflow-worker-{w}"))
                .spawn(move || {
                    let pool = match ExecutorPool::load(&dir) {
                        Ok(p) => {
                            let _ = ready_tx.send(Ok(()));
                            p
                        }
                        Err(e) => {
                            let _ = ready_tx.send(Err(format!("{e:#}")));
                            return 0;
                        }
                    };
                    run_worker(&pool, &queue, policy, &stats)
                })
                .context("spawning worker")?;
            ready_rx
                .recv()
                .context("worker died before reporting readiness")?
                .map_err(|e| anyhow::anyhow!("worker {w} failed to load artifacts: {e}"))?;
            workers.push(handle);
        }
        Ok(Server {
            tx: Some(tx),
            workers,
            stats,
            next_id: AtomicU64::new(1),
            started: Instant::now(),
        })
    }

    /// Submit one image; returns the reply channel.
    pub fn submit(&self, image: Vec<i32>) -> Result<Receiver<InferResponse>> {
        validate_image(&image)?;
        let (reply, rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.tx
            .as_ref()
            .context("server is shut down")?
            .send(Job {
                req: InferRequest {
                    id,
                    image,
                    enqueued_at: Instant::now(),
                },
                reply,
            })
            .ok()
            .context("worker queue closed")?;
        Ok(rx)
    }

    /// Submit and block for the response.
    pub fn submit_wait(&self, image: Vec<i32>) -> Result<InferResponse> {
        let rx = self.submit(image)?;
        rx.recv().context("inference dropped (execution failed?)")
    }

    /// Snapshot serving statistics.
    pub fn stats(&self) -> StatsSnapshot {
        let s = self.stats.lock().expect("stats lock poisoned");
        StatsSnapshot {
            served: s.served,
            batches: s.batches,
            mean_batch: s.mean_batch(),
            latency: Summary::from_samples(s.latencies_s.clone()),
            exec: Summary::from_samples(s.exec_s.clone()),
        }
    }

    /// Requests served per wall-clock second since start.
    pub fn throughput(&self) -> f64 {
        let s = self.stats.lock().expect("stats lock poisoned");
        s.served as f64 / self.started.elapsed().as_secs_f64()
    }

    /// Stop accepting requests, drain, and join workers. Returns total
    /// requests served.
    pub fn shutdown(mut self) -> u64 {
        self.tx.take(); // close the queue
        let mut total = 0;
        for w in self.workers.drain(..) {
            total += w.join().unwrap_or(0);
        }
        total
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.tx.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::IMAGE_ELEMENTS;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn serves_requests_end_to_end() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let server = Server::start(&dir, ServerConfig::default()).unwrap();
        let mut rng = crate::util::Rng::new(11);
        let mut pending = Vec::new();
        for _ in 0..6 {
            let img: Vec<i32> = (0..IMAGE_ELEMENTS)
                .map(|_| rng.range_i64(0, 255) as i32)
                .collect();
            pending.push(server.submit(img).unwrap());
        }
        for rx in pending {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.logits.len(), 100);
            assert!(resp.latency_s >= 0.0);
            assert!(resp.batch >= 1);
        }
        let snap = server.stats();
        assert_eq!(snap.served, 6);
        assert!(snap.batches >= 1);
        let total = server.shutdown();
        assert_eq!(total, 6);
    }

    #[test]
    fn same_image_gives_same_logits() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let server = Server::start(&dir, ServerConfig::default()).unwrap();
        let img = vec![7i32; IMAGE_ELEMENTS];
        let a = server.submit_wait(img.clone()).unwrap();
        let b = server.submit_wait(img).unwrap();
        assert_eq!(a.logits, b.logits);
        assert_ne!(a.id, b.id);
    }

    #[test]
    fn rejects_bad_images() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let server = Server::start(&dir, ServerConfig::default()).unwrap();
        assert!(server.submit(vec![1, 2, 3]).is_err());
        assert!(server.submit(vec![999; IMAGE_ELEMENTS]).is_err());
    }
}
