//! Chip-level facade: the performance/energy queries every scheduler layer
//! (partition, mapping, DDM, pipeline, sim) asks of the hardware.

use crate::cfg::chip::{CellTech, ChipConfig};
use crate::nn::Layer;

use super::{area, buffer, noc, pe, subarray, tile};

/// Crossbar weight-programming energy, pJ per weight (RRAM SET/RESET pulses
/// across 4 cells vs SRAM write).
pub fn wprog_pj_per_weight(cell: CellTech) -> f64 {
    match cell {
        CellTech::Rram { .. } => 40.0,
        CellTech::Sram => 2.0,
    }
}

/// The chip macro-model: validated config + derived query methods.
#[derive(Debug, Clone)]
pub struct ChipModel {
    pub cfg: ChipConfig,
}

impl ChipModel {
    pub fn new(cfg: ChipConfig) -> anyhow::Result<Self> {
        cfg.validate()?;
        Ok(ChipModel { cfg })
    }

    /// Subarrays one copy of `layer`'s weights occupies.
    pub fn layer_subarrays(&self, layer: &Layer) -> u64 {
        subarray::subarrays_for(&self.cfg, layer.crossbar_k(), layer.crossbar_n())
    }

    /// Tiles one copy of `layer` occupies (minimum mapping granularity).
    pub fn layer_tiles(&self, layer: &Layer) -> u32 {
        tile::tiles_for_matrix(&self.cfg, layer.crossbar_k(), layer.crossbar_n())
    }

    /// Per-IFM latency of `layer` with duplication factor `dup`:
    /// `⌈O²/dup⌉ × t_mvm` (paper §II-D: inference time ∝ O×O; PipeLayer-
    /// style duplication divides the sequential MVM count).
    pub fn layer_latency_ns(&self, layer: &Layer, dup: u32) -> f64 {
        let dup = dup.max(1) as u64;
        let mvms = layer.out_pixels().div_ceil(dup);
        mvms as f64 * self.cfg.t_mvm_ns()
    }

    /// Maximum useful duplication for `layer`: `O²` copies collapse the
    /// layer to a single MVM round (paper: `MAX[i]` from O×O, e.g. O=8 →
    /// up to 64).
    pub fn max_dup(&self, layer: &Layer) -> u32 {
        layer.out_pixels().min(u32::MAX as u64) as u32
    }

    /// Per-IFM compute energy of `layer`, pJ: every output pixel activates
    /// all of the layer's subarrays once (duplication redistributes work
    /// but not the activation count), plus PE accumulation and buffer/NoC
    /// activation traffic.
    pub fn layer_compute_pj(&self, layer: &Layer) -> f64 {
        let s = self.layer_subarrays(layer);
        let mvm = layer.out_pixels() as f64 * subarray::mvm_energy_pj(&self.cfg, s);
        let accum = layer.out_pixels() as f64 * pe::accum_energy_pj(&self.cfg, s);
        let traffic = buffer::layer_traffic_pj(&self.cfg, layer.ifm_bytes(), layer.ofm_bytes())
            + noc::transfer_pj(&self.cfg, layer.ifm_bytes() + layer.ofm_bytes());
        mvm + accum + traffic
    }

    /// Energy to program one copy of `layer`'s weights into crossbars, pJ.
    pub fn layer_wprog_pj(&self, layer: &Layer) -> f64 {
        layer.weights() as f64 * wprog_pj_per_weight(self.cfg.cell)
    }

    /// Whole-chip leakage power, W.
    pub fn leak_w(&self) -> f64 {
        self.cfg.num_tiles as f64 * self.cfg.p_leak_mw_per_tile * 1e-3
    }

    pub fn area_mm2(&self) -> f64 {
        area::chip_area_mm2(&self.cfg)
    }

    pub fn num_tiles(&self) -> u32 {
        self.cfg.num_tiles
    }

    /// Can the whole network reside on-chip at once?
    pub fn fits_entirely(&self, total_tiles: u32) -> bool {
        total_tiles <= self.cfg.num_tiles
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cfg::presets;
    use crate::nn::resnet;

    fn chip() -> ChipModel {
        ChipModel::new(presets::compact_rram_41mm2()).unwrap()
    }

    #[test]
    fn latency_divides_by_dup() {
        let c = chip();
        let l = crate::nn::Layer::conv("l", 32, 64, 64, 3, 1, 1); // O²=1024
        let t1 = c.layer_latency_ns(&l, 1);
        let t4 = c.layer_latency_ns(&l, 4);
        assert!((t1 / t4 - 4.0).abs() < 1e-9);
        // full duplication collapses to one MVM round
        let tmax = c.layer_latency_ns(&l, c.max_dup(&l));
        assert!((tmax - c.cfg.t_mvm_ns()).abs() < 1e-9);
    }

    #[test]
    fn dup_zero_treated_as_one() {
        let c = chip();
        let l = crate::nn::Layer::conv("l", 8, 8, 8, 3, 1, 1);
        assert_eq!(c.layer_latency_ns(&l, 0), c.layer_latency_ns(&l, 1));
    }

    #[test]
    fn energy_independent_of_duplication_claim() {
        // layer_compute_pj has no dup argument by design: duplication moves
        // work in time, not in activation count.
        let c = chip();
        let l = crate::nn::Layer::conv("l", 16, 32, 32, 3, 1, 1);
        assert!(c.layer_compute_pj(&l) > 0.0);
    }

    #[test]
    fn resnet34_energy_order_of_magnitude() {
        // ≈ MACs/4096 × 800 pJ ≈ 250 µJ per IFM for CIFAR ResNet-34.
        let c = chip();
        let net = resnet::resnet34(100);
        let total_pj: f64 = net
            .crossbar_layers()
            .iter()
            .map(|l| c.layer_compute_pj(l))
            .sum();
        let uj = total_pj * 1e-6;
        assert!(uj > 50.0 && uj < 2000.0, "{uj} µJ/IFM");
    }

    #[test]
    fn max_dup_follows_out_pixels() {
        let c = chip();
        let l8 = crate::nn::Layer::conv("l", 8, 8, 8, 3, 1, 1); // O=8
        assert_eq!(c.max_dup(&l8), 64);
    }

    #[test]
    fn fc_layer_one_mvm() {
        let c = chip();
        let fc = crate::nn::Layer::fc("fc", 512, 100);
        assert_eq!(c.layer_latency_ns(&fc, 1), c.cfg.t_mvm_ns());
    }
}
