//! Request/response types for the serving coordinator.

use std::time::Instant;

/// Unique request id.
pub type RequestId = u64;

/// One inference request: a CIFAR-shaped image, u8-range i32 values.
#[derive(Debug, Clone)]
pub struct InferRequest {
    pub id: RequestId,
    /// Flattened (32, 32, 3) image, values 0..=255.
    pub image: Vec<i32>,
    pub enqueued_at: Instant,
}

/// Completed inference.
#[derive(Debug, Clone)]
pub struct InferResponse {
    pub id: RequestId,
    /// 100-way int32 logits.
    pub logits: Vec<i32>,
    /// Queueing + batching + execution latency, seconds.
    pub latency_s: f64,
    /// Batch size this request was served in.
    pub batch: usize,
}

impl InferResponse {
    /// Argmax class index.
    pub fn top_class(&self) -> usize {
        self.logits
            .iter()
            .enumerate()
            .max_by_key(|(_, &v)| v)
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
}

/// Expected image element count (32·32·3).
pub const IMAGE_ELEMENTS: usize = 32 * 32 * 3;

/// Validate an image payload.
pub fn validate_image(image: &[i32]) -> anyhow::Result<()> {
    anyhow::ensure!(
        image.len() == IMAGE_ELEMENTS,
        "image must have {IMAGE_ELEMENTS} elements, got {}",
        image.len()
    );
    anyhow::ensure!(
        image.iter().all(|&v| (0..=255).contains(&v)),
        "image values must be u8-range"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validates_shape_and_range() {
        assert!(validate_image(&vec![0; IMAGE_ELEMENTS]).is_ok());
        assert!(validate_image(&vec![0; 10]).is_err());
        assert!(validate_image(&vec![256; IMAGE_ELEMENTS]).is_err());
        assert!(validate_image(&vec![-1; IMAGE_ELEMENTS]).is_err());
    }

    #[test]
    fn top_class_is_argmax() {
        let mut logits = vec![0i32; 100];
        logits[42] = 7;
        let r = InferResponse {
            id: 1,
            logits,
            latency_s: 0.0,
            batch: 1,
        };
        assert_eq!(r.top_class(), 42);
    }
}
