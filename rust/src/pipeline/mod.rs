//! The paper's pipeline method for compact PIM chips (Fig. 4):
//! closed-form [`case`] formulas, per-part [`schedule`] timing,
//! [`bubble`] accounting, and the batch-level [`sim`] simulator.

pub mod bubble;
pub mod case;
pub mod schedule;
pub mod sim;

pub use sim::{simulate, PartExec, PipelineReport};
