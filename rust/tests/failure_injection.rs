//! Failure injection: corrupted artifacts, degenerate networks, hostile
//! configs — everything must fail loudly and cleanly, never hang or UB.

use pimflow::cfg::presets;
use pimflow::nn::{Layer, Network};
use pimflow::partition::partition;
use pimflow::pim::ChipModel;
use pimflow::sim::System;

// ---------- artifact-layer failures (runtime feature only) ----------

#[cfg(feature = "runtime")]
mod artifact_failures {
    use std::path::PathBuf;

    use pimflow::runtime::{ExecutorPool, Manifest};

    fn tmpdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("pimflow_fail_{name}"));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn missing_manifest_is_a_clean_error() {
        let dir = tmpdir("nomanifest");
        let err = Manifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("manifest"), "{err}");
    }

    #[test]
    fn corrupted_manifest_json_is_rejected() {
        let dir = tmpdir("badjson");
        std::fs::write(dir.join("manifest.json"), "{not json").unwrap();
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn manifest_missing_fields_is_rejected() {
        let dir = tmpdir("nofields");
        std::fs::write(dir.join("manifest.json"), r#"{"version": 2}"#).unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version": 2, "entries": {"x": {"inputs": [], "outputs": []}}}"#,
        )
        .unwrap();
        assert!(Manifest::load(&dir).is_err()); // no file field
    }

    #[test]
    fn truncated_hlo_text_fails_at_compile() {
        let dir = tmpdir("badhlo");
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version": 2, "entries": {"tiny_cnn_b1": {
            "file": "t.hlo.txt",
            "inputs": [{"shape": [1,32,32,3], "dtype": "i32"}],
            "outputs": [{"shape": [1,100], "dtype": "i32"}]}}}"#,
        )
        .unwrap();
        std::fs::write(dir.join("t.hlo.txt"), "HloModule truncated_garbage {").unwrap();
        assert!(ExecutorPool::load(&dir).is_err());
    }

    #[test]
    fn hlo_file_absent_fails_at_load() {
        let dir = tmpdir("nofile");
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version": 2, "entries": {"tiny_cnn_b1": {
            "file": "missing.hlo.txt",
            "inputs": [{"shape": [1,32,32,3], "dtype": "i32"}],
            "outputs": [{"shape": [1,100], "dtype": "i32"}]}}}"#,
        )
        .unwrap();
        assert!(ExecutorPool::load(&dir).is_err());
    }
}

// ---------- simulator-layer failures & degenerate inputs ----------

#[test]
fn single_layer_network_simulates() {
    let mut net = Network::new("one", 8, 3);
    net.push(Layer::conv("only", 8, 3, 16, 3, 1, 1));
    let r = System::new(presets::compact_rram_41mm2(), presets::lpddr5())
        .try_run(&net, 4)
        .unwrap();
    assert_eq!(r.num_parts, 1);
    assert!(r.throughput_fps > 0.0);
}

#[test]
fn fc_only_network_simulates_without_duplication() {
    let mut net = Network::new("fc_only", 1, 1);
    net.push(Layer::fc("fc1", 512, 512));
    net.push(Layer::fc("fc2", 512, 100));
    let sys = System::new(presets::compact_rram_41mm2(), presets::lpddr5());
    let r = sys.try_run(&net, 8).unwrap();
    assert!(r.throughput_fps > 0.0);
    // DDM must not have duplicated FC layers — identical to no-DDM.
    let no = sys.with_ddm(false).try_run(&net, 8).unwrap();
    assert!((r.throughput_fps - no.throughput_fps).abs() / no.throughput_fps < 1e-9);
}

#[test]
fn network_larger_than_chip_capacity_channel_splits() {
    // A single conv whose weights exceed the whole compact chip.
    let mut net = Network::new("giant", 8, 2048);
    net.push(Layer::conv("huge", 8, 2048, 2048, 3, 1, 1)); // 37.7M weights
    let chip = ChipModel::new(presets::compact_rram_41mm2()).unwrap();
    let plan = partition(&net, &chip).unwrap();
    assert!(plan.num_parts() > 1);
    assert_eq!(plan.total_weights(), net.total_weights());
    let r = System::new(presets::compact_rram_41mm2(), presets::lpddr5())
        .try_run(&net, 2)
        .unwrap();
    assert!(r.throughput_fps > 0.0);
}

#[test]
fn empty_network_is_rejected() {
    let net = Network::new("empty", 32, 3);
    assert!(System::new(presets::compact_rram_41mm2(), presets::lpddr5())
        .try_run(&net, 1)
        .is_err());
}

#[test]
fn zero_dimension_layer_is_rejected() {
    let mut net = Network::new("zero", 8, 3);
    net.push(Layer::conv("bad", 0, 3, 8, 3, 1, 1));
    assert!(System::new(presets::compact_rram_41mm2(), presets::lpddr5())
        .try_run(&net, 1)
        .is_err());
}

#[test]
fn hostile_chip_configs_error_not_panic() {
    use pimflow::cfg::chip::CellTech;
    let base = presets::compact_rram_41mm2();
    for mutate in [
        Box::new(|c: &mut pimflow::cfg::ChipConfig| c.num_tiles = 0)
            as Box<dyn Fn(&mut pimflow::cfg::ChipConfig)>,
        Box::new(|c| c.subarray_rows = 0),
        Box::new(|c| c.t_read_ns = -1.0),
        Box::new(|c| c.weight_bits = 7),
        Box::new(|c| {
            c.cell = CellTech::Rram { bits_per_cell: 3 };
        }),
    ] {
        let mut cfg = base.clone();
        mutate(&mut cfg);
        assert!(
            System::new(cfg, presets::lpddr5())
                .try_run(&pimflow::nn::resnet::tiny(100), 1)
                .is_err(),
            "hostile config accepted"
        );
    }
}

#[test]
fn toml_config_attack_surface() {
    // Deep nesting, huge numbers, duplicate keys, broken strings.
    for bad in [
        "batch = 99999999999999999999999999",
        "a = 1\na = 2",
        "s = \"unterminated",
        "[sim]\nbatch = -5",
        "[sim]\npipeline_case = \"nonsense\"",
    ] {
        assert!(
            pimflow::cfg::Config::from_str(bad).is_err(),
            "accepted: {bad}"
        );
    }
}

#[test]
fn batch_zero_is_rejected_by_simulator() {
    let err = System::new(presets::compact_rram_41mm2(), presets::lpddr5())
        .try_run(&pimflow::nn::resnet::tiny(100), 0);
    assert!(err.is_err());
}

// ---------- hostile fault-plan specs (chaos layer) ----------

#[test]
fn hostile_fault_specs_error_not_panic() {
    use pimflow::coordinator::FaultPlan;
    for bad in [
        "crash",                         // bare kind
        "crash:w0",                      // no schedule
        "crash:x0@1s+1s",                // bad worker tag
        "crash:w0@1s",                   // missing downtime
        "crash:w0@1s+1s+1s",             // extra field
        "crash:w0@-1s+1s",               // negative onset
        "crash:w0@1s+0s",                // zero downtime
        "crash:w0@nans+1s",              // non-finite onset
        "dramslow:0.5@1s..2s",           // factor without x
        "dramslow:0x@1s..2s",            // zero factor
        "dramslow:1.5x@1s..2s",          // speed-up, not a brownout
        "dramslow:0.5x@2s..2s",          // empty window
        "dramslow:0.5x@2s..1s",          // inverted window
        "dramslow:0.5x@1s",              // no window at all
        "straggle:w0",                   // no factor
        "straggle:w0:0.5x",              // faster-than-1 straggler
        "straggle:w0:2x,straggle:w0:3x", // duplicate worker
        "crash:w0@1s+1s,,straggle:w0:2x", // empty term
        "wobble:w0:2x",                  // unknown fault kind
    ] {
        assert!(FaultPlan::parse(bad).is_err(), "accepted: {bad}");
    }
}

#[test]
fn fault_plans_naming_absent_workers_are_rejected_at_build() {
    use pimflow::coordinator::{FaultPlan, SimServeConfig};
    use pimflow::explore::trace::replay;
    use pimflow::nn::zoo;
    use pimflow::sim::Engine;

    let eng = Engine::compact(presets::lpddr5());
    let nets = [zoo::by_name("mobilenetv1", 100).unwrap()];
    for spec in ["crash:w2@1s+1s", "straggle:w7:2x"] {
        let cfg = SimServeConfig {
            workers: 2,
            faults: FaultPlan::parse(spec).unwrap(), // parses fine in isolation
            ..SimServeConfig::default()
        };
        let err = replay(&eng, &nets, &[], cfg).unwrap_err().to_string();
        assert!(err.contains("worker"), "spec `{spec}` gave: {err}");
    }
}
