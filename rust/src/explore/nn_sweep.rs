//! NN-size exploration (Fig. 8): deploy each network of a family on the
//! fixed compact chip and find the largest one that still meets the
//! performance floor (paper: energy efficiency > 8 TOPS/W and throughput
//! > 3000 FPS → deploy NNs smaller than ResNet-101).
//!
//! The network axis is data: [`fig8_sweep`] takes any list of networks —
//! the paper's ResNet family ([`paper_networks`]), the whole model zoo
//! ([`zoo_sweep`]), or an arbitrary selection resolved through
//! [`crate::nn::zoo::by_name`].
//!
//! Runs through the shared [`Engine`]: the three designs of each network
//! fan out in parallel and the per-network plans land in the plan cache,
//! so follow-up sweeps (other batches, the `explore` floor search) reuse
//! them.

use anyhow::Result;

use crate::nn::{resnet, zoo, Network};
use crate::sim::engine::{find_net, Design, DesignPoint, Engine};

/// Reference batch used for the exploration.
pub const EXPLORE_BATCH: u32 = 256;

/// The paper's Fig. 8 x-axis: the ResNet family, smallest to largest.
pub fn paper_networks() -> Vec<Network> {
    resnet::paper_family(100)
}

/// Sweep `nets` on the compact chip. Returns the flat grid of
/// (network × {no-DDM, DDM, unlimited}) rows at one batch size, in the
/// given network order.
pub fn fig8_sweep(engine: &Engine, nets: &[Network], batch: u32) -> Result<Vec<DesignPoint>> {
    let mut points = Vec::new();
    for net in nets {
        points.extend(engine.sweep(net, &Design::FIG8, &[batch])?);
    }
    Ok(points)
}

/// [`fig8_sweep`] over the whole model zoo (ResNets + VGGs + MobileNet),
/// sorted by weight count so the rows read as a size axis.
pub fn zoo_sweep(engine: &Engine, batch: u32) -> Result<Vec<DesignPoint>> {
    fig8_sweep(engine, &zoo::all_sorted(), batch)
}

/// Performance floor for the deployment recommendation.
#[derive(Debug, Clone, Copy)]
pub struct Floor {
    pub min_tops_per_watt: f64,
    pub min_fps: f64,
}

/// The largest network (by weights) whose compact+DDM point meets `floor`.
pub fn max_deployable(points: &[DesignPoint], floor: Floor) -> Option<&DesignPoint> {
    points
        .iter()
        .filter(|p| {
            p.design == Design::CompactDdm
                && p.tops_per_watt > floor.min_tops_per_watt
                && p.throughput_fps > floor.min_fps
        })
        .max_by_key(|p| p.weights)
}

/// The DDM row for one network of a [`fig8_sweep`] result.
pub fn ddm_row<'a>(points: &'a [DesignPoint], network: &str) -> Option<&'a DesignPoint> {
    find_net(points, Design::CompactDdm, network)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cfg::presets;

    fn sweep() -> Vec<DesignPoint> {
        fig8_sweep(&Engine::compact(presets::lpddr5()), &paper_networks(), 64).unwrap()
    }

    fn ddm_points(pts: &[DesignPoint]) -> Vec<&DesignPoint> {
        pts.iter()
            .filter(|p| p.design == Design::CompactDdm)
            .collect()
    }

    #[test]
    fn throughput_decreases_with_nn_size() {
        // Paper: "inference throughput decreases rapidly as the NN grows".
        // Partition/DDM luck can wobble a single step (R101→R152 gains a
        // few %), so assert the trend: no step regresses upward by >15%
        // and the family's endpoints differ by >2×.
        let pts = sweep();
        let ddm = ddm_points(&pts);
        assert_eq!(ddm.len(), 5, "one DDM row per family member");
        for w in ddm.windows(2) {
            assert!(
                w[1].throughput_fps < w[0].throughput_fps * 1.15,
                "{} vs {}",
                w[0].network,
                w[1].network
            );
        }
        let first = ddm.first().unwrap().throughput_fps;
        let last = ddm.last().unwrap().throughput_fps;
        assert!(last < first / 2.0, "endpoints {first} vs {last}");
    }

    #[test]
    fn efficiency_stays_in_regime() {
        // Paper: energy efficiency fluctuates slightly but stays >8 TOPS/W.
        let pts = sweep();
        let ddm = ddm_points(&pts);
        for p in &ddm {
            assert!(
                p.tops_per_watt > 2.0,
                "{}: {} TOPS/W",
                p.network,
                p.tops_per_watt
            );
        }
        let effs: Vec<f64> = ddm.iter().map(|p| p.tops_per_watt).collect();
        let min = effs.iter().copied().fold(f64::INFINITY, f64::min);
        let max = effs.iter().copied().fold(0.0, f64::max);
        assert!(max / min < 4.0, "efficiency swing too wide: {effs:?}");
    }

    #[test]
    fn max_deployable_respects_floor() {
        let pts = sweep();
        // A floor nothing meets:
        assert!(max_deployable(
            &pts,
            Floor {
                min_tops_per_watt: 1e9,
                min_fps: 1e12
            }
        )
        .is_none());
        // A floor everything meets returns the largest net:
        let all = max_deployable(
            &pts,
            Floor {
                min_tops_per_watt: 0.0,
                min_fps: 0.0,
            },
        )
        .unwrap();
        assert_eq!(all.network, "resnet152");
        assert_eq!(all.design, Design::CompactDdm);
    }

    #[test]
    fn paper_style_floor_selects_mid_family() {
        // With a floor between the family's extremes the answer must be a
        // strict subset boundary (the paper lands between R50 and R101).
        let pts = sweep();
        let ddm = ddm_points(&pts);
        let mid_fps = (ddm.last().unwrap().throughput_fps + ddm[0].throughput_fps) / 2.0;
        let pick = max_deployable(
            &pts,
            Floor {
                min_tops_per_watt: 0.0,
                min_fps: mid_fps,
            },
        )
        .unwrap();
        assert_ne!(pick.network, "resnet152");
    }

    #[test]
    fn ddm_row_lookup_finds_networks() {
        let pts = sweep();
        assert!(ddm_row(&pts, "resnet50").is_some());
        assert!(ddm_row(&pts, "resnet9999").is_none());
    }
}
