//! Off-chip DRAM model (DRAMPower-substitute): the spec tables live in
//! [`crate::cfg::dram`]; this module adds the transaction [`trace`] (the
//! paper's *(time, r/w, 32-bit address)* recording) and the stateful
//! [`controller`] that converts traffic into latency + energy.

pub mod controller;
pub mod export;
pub mod trace;

pub use controller::DramController;
pub use trace::{Trace, Transaction, TxKind, TxPayload};
