//! DRAM controller façade: schedule reads/writes, accumulate energy, and
//! record the transaction trace (DRAMPower-substitute accounting).

use crate::cfg::dram::DramConfig;

use super::trace::{Trace, TxKind, TxPayload};

/// Stateful controller: owns the trace and energy counters.
#[derive(Debug, Clone)]
pub struct DramController {
    pub cfg: DramConfig,
    trace: Trace,
    energy_j: f64,
}

impl DramController {
    pub fn new(cfg: DramConfig) -> Self {
        DramController {
            cfg,
            trace: Trace::new(),
            energy_j: 0.0,
        }
    }

    /// Issue a read of `bytes` at `time_ns`; returns the transfer latency
    /// in ns.
    pub fn read(&mut self, time_ns: f64, bytes: u64, payload: TxPayload) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        self.trace.record(time_ns, TxKind::Read, bytes, payload);
        self.energy_j += self.cfg.read_energy_j(bytes);
        self.cfg.transfer_ns(bytes)
    }

    /// Issue a write of `bytes` at `time_ns`; returns the latency in ns.
    pub fn write(&mut self, time_ns: f64, bytes: u64, payload: TxPayload) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        self.trace.record(time_ns, TxKind::Write, bytes, payload);
        self.energy_j += self.cfg.write_energy_j(bytes);
        self.cfg.transfer_ns(bytes)
    }

    /// Transaction energy so far (excludes background), J.
    pub fn transaction_energy_j(&self) -> f64 {
        self.energy_j
    }

    /// Background energy for a window of `window_s` seconds, J.
    pub fn background_energy_j(&self, window_s: f64) -> f64 {
        self.cfg.background_energy_j(window_s)
    }

    /// Total DRAM energy for a run that spanned `window_s`, J.
    pub fn total_energy_j(&self, window_s: f64) -> f64 {
        self.energy_j + self.background_energy_j(window_s)
    }

    pub fn trace(&self) -> &Trace {
        &self.trace
    }

    /// Bus burst size in bytes (one column access across the bus).
    pub fn burst_bytes(&self) -> u64 {
        // BL16 on LPDDR4/5, BL8 on LPDDR3; both land on bus_bits*16/8 ≈ 256B
        // for a 128-bit bus. Use bus width × 16 beats.
        (self.cfg.bus_bits as u64 / 8) * 16
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cfg::presets;

    #[test]
    fn read_write_accumulate_energy_and_trace() {
        let mut c = DramController::new(presets::lpddr5());
        let lat = c.read(0.0, 1 << 20, TxPayload::Weights);
        assert!(lat > 0.0);
        c.write(lat, 1 << 10, TxPayload::Intermediate);
        assert_eq!(c.trace().len(), 2);
        assert!(c.transaction_energy_j() > 0.0);
    }

    #[test]
    fn zero_byte_ops_are_free() {
        let mut c = DramController::new(presets::lpddr5());
        assert_eq!(c.read(0.0, 0, TxPayload::Input), 0.0);
        assert_eq!(c.trace().len(), 0);
        assert_eq!(c.transaction_energy_j(), 0.0);
    }

    #[test]
    fn background_scales_with_window() {
        let c = DramController::new(presets::lpddr5());
        let e1 = c.background_energy_j(1.0);
        let e2 = c.background_energy_j(2.0);
        assert!((e2 - 2.0 * e1).abs() < 1e-12);
    }

    #[test]
    fn lpddr3_slower_than_lpddr5() {
        let mut c3 = DramController::new(presets::lpddr3());
        let mut c5 = DramController::new(presets::lpddr5());
        let l3 = c3.read(0.0, 1 << 20, TxPayload::Weights);
        let l5 = c5.read(0.0, 1 << 20, TxPayload::Weights);
        assert!(l3 > 2.0 * l5);
        assert!(c3.transaction_energy_j() > 2.0 * c5.transaction_energy_j());
    }

    #[test]
    fn burst_bytes_for_128bit_bus() {
        let c = DramController::new(presets::lpddr5());
        assert_eq!(c.burst_bytes(), 256);
    }
}
