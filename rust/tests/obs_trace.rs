//! Tier-1 pins for the observability layer (`obs`):
//!
//! * **bitwise inertness when disabled** — a replay with no trace sink and
//!   no movement ledger attached is the pre-observability replay, bit for
//!   bit, across placement × replication × fault plans;
//! * **byte-identical double runs** — the same pinned faulted workload
//!   exported twice produces byte-identical Chrome-trace JSON and metrics
//!   text/CSV (no wall-clock, no RNG, sorted iteration everywhere);
//! * **Chrome `trace_event` shape** — the in-repo JSON parser validates
//!   every emitted event, lanes are named, and the span taxonomy (exec /
//!   reload / prewarm spans; batch_open / crash / recover /
//!   controller_tick instants; dram_brownout windows; plan-ladder
//!   provenance) shows up under the expected categories;
//! * **streaming = buffered** — the O(1)-memory streaming sink writes the
//!   exact bytes the buffered sink renders;
//! * **movement attribution** — the data-movement energy share decreases
//!   monotonically along a growing `max_batch` ladder (the paper's Fig. 7
//!   argument at fleet scale).

use pimflow::cfg::presets;
use pimflow::coordinator::{
    AdaptiveConfig, FaultPlan, Placement, ReplicationPolicy, SimRequest, SimServeConfig,
    SimServeReport,
};
use pimflow::explore::trace::{mixed_trace, movement_sweep, replay, replay_obs};
use pimflow::nn::{zoo, Network};
use pimflow::obs::{event_counts, validate_chrome_trace, Registry, TraceSink};
use pimflow::sim::Engine;

fn engine() -> Engine {
    Engine::compact(presets::lpddr5())
}

/// The pinned skewed workload shared with `tests/chaos_sim.rs`: one hot
/// network every other request, three cold ones cycling behind it.
fn skewed_nets() -> Vec<Network> {
    ["mobilenetv1", "vgg11", "resnet18", "vgg13"]
        .iter()
        .map(|n| zoo::by_name(n, 100).unwrap())
        .collect()
}

fn skewed_trace(n: usize) -> Vec<SimRequest> {
    (0..n)
        .map(|j| SimRequest {
            id: j as u64,
            net: if j % 2 == 0 { 0 } else { 1 + (j / 2) % 3 },
            arrival_s: j as f64 * 0.025,
        })
        .collect()
}

fn base_cfg() -> SimServeConfig {
    SimServeConfig {
        slo_s: 1e6,
        max_batch: 8,
        max_wait_s: 0.001,
        workers: 3,
        placement: Placement::NetworkAffinity,
        ..SimServeConfig::default()
    }
}

/// The pinned chaos scenario from `tests/chaos_sim.rs`: adaptive
/// replication with the hot-network worker crashed mid-trace.
fn faulted_cfg() -> SimServeConfig {
    SimServeConfig {
        replication: ReplicationPolicy::Adaptive(AdaptiveConfig::default()),
        faults: FaultPlan::parse("crash:w0@3.0005s+1.0s").unwrap(),
        ..base_cfg()
    }
}

/// Bitwise equality on every externally visible report dimension.
fn assert_bitwise_equal(a: &SimServeReport, b: &SimServeReport, label: &str) {
    assert_eq!(a.accepted(), b.accepted(), "{label}: accepted");
    assert_eq!(a.coalesced(), b.coalesced(), "{label}: coalesced");
    assert_eq!(a.rejected(), b.rejected(), "{label}: rejected");
    assert_eq!(a.batches(), b.batches(), "{label}: batches");
    assert_eq!(a.reloads(), b.reloads(), "{label}: reloads");
    assert_eq!(a.prewarms(), b.prewarms(), "{label}: prewarms");
    assert_eq!(a.goodput(), b.goodput(), "{label}: goodput");
    assert_eq!(a.span_s.to_bits(), b.span_s.to_bits(), "{label}: span");
    assert_eq!(a.completions.len(), b.completions.len(), "{label}: completions");
    for (x, y) in a.completions.iter().zip(&b.completions) {
        assert_eq!(x.id, y.id, "{label}: completion order");
        assert_eq!(x.worker, y.worker, "{label}: worker of request {}", x.id);
        assert_eq!(
            x.completion_s.to_bits(),
            y.completion_s.to_bits(),
            "{label}: completion time of request {}",
            x.id
        );
    }
    assert_eq!(a.replica_holders, b.replica_holders, "{label}: residency");
    for (x, y) in a.per_worker.iter().zip(&b.per_worker) {
        assert_eq!(x.busy_s.to_bits(), y.busy_s.to_bits(), "{label}: worker {} busy", x.id);
        assert_eq!(
            x.idle_at_s.to_bits(),
            y.idle_at_s.to_bits(),
            "{label}: worker {} idle-at",
            x.id
        );
    }
}

#[test]
fn disabled_sinks_are_bitwise_inert_across_the_policy_grid() {
    // `replay_obs` with nothing attached must BE `replay`: no sink checks
    // change arithmetic, no extra events, no perturbed ordering. Pinned
    // across every placement × a replication ladder × fault plans so the
    // instrumentation hooks in flush/crash/prewarm/controller paths are
    // all covered by a disabled-path replay.
    let nets = skewed_nets();
    let trace = skewed_trace(120);
    let policies = [
        ReplicationPolicy::None,
        ReplicationPolicy::Static { targets: vec![("mobilenetv1".to_string(), 2)] },
        ReplicationPolicy::Adaptive(AdaptiveConfig::default()),
    ];
    let plans = [
        FaultPlan::default(),
        FaultPlan::parse("crash:w0@1.5s+0.5s,dramslow:0.5x@0.5s..2.5s").unwrap(),
    ];
    for placement in Placement::ALL {
        for policy in &policies {
            for faults in &plans {
                let cfg = SimServeConfig {
                    placement,
                    replication: policy.clone(),
                    faults: faults.clone(),
                    ..base_cfg()
                };
                let plain = replay(&engine(), &nets, &trace, cfg.clone()).unwrap();
                let obs = replay_obs(&engine(), &nets, &trace, cfg, None, false).unwrap();
                let label = format!(
                    "{} / {} / faults {}",
                    placement.label(),
                    policy.label(),
                    !faults.is_off()
                );
                assert!(obs.trace.is_none(), "{label}: no sink, no trace");
                assert!(obs.movement.is_none(), "{label}: no ledger, no movement");
                assert_bitwise_equal(&plain, &obs, &label);
            }
        }
    }
}

/// One instrumented run of the pinned faulted workload: fresh engine,
/// buffered sink + movement ledger, full metrics registry. Returns the
/// rendered trace JSON and both metrics exports.
fn instrumented_run() -> (SimServeReport, String, String, String) {
    let eng = engine().with_plan_events();
    let nets = skewed_nets();
    let trace = skewed_trace(240);
    let report = replay_obs(
        &eng,
        &nets,
        &trace,
        faulted_cfg(),
        Some(TraceSink::buffered()),
        true,
    )
    .unwrap();
    let json = report
        .trace
        .as_ref()
        .expect("buffered sink reaches the report")
        .json
        .clone()
        .expect("buffered sinks render JSON in-memory");
    let mut reg = Registry::new();
    report.register_metrics(&mut reg);
    eng.cache_stats().register(&mut reg);
    (report, json, reg.to_text(), reg.to_csv())
}

#[test]
fn double_runs_export_byte_identical_trace_and_metrics() {
    let (r1, json1, text1, csv1) = instrumented_run();
    let (r2, json2, text2, csv2) = instrumented_run();
    assert_bitwise_equal(&r1, &r2, "instrumented double run");
    assert_eq!(json1, json2, "trace JSON must be byte-identical across runs");
    assert_eq!(text1, text2, "metrics text must be byte-identical across runs");
    assert_eq!(csv1, csv2, "metrics CSV must be byte-identical across runs");

    // The export is a valid Chrome trace, and the counted events match
    // what the sink reported.
    let done = r1.trace.as_ref().unwrap();
    let n = validate_chrome_trace(&json1).expect("well-formed trace_event JSON");
    assert_eq!(n as u64, done.events, "validator count vs sink count");
    assert!(done.high_water > 0, "buffered sinks hold the whole trace");

    // Span taxonomy under the pinned chaos scenario: executions, weight
    // reloads, adaptive pre-warms, batch opens, the crash/recover pair
    // with its down window, controller ticks, residency churn, and
    // plan-ladder provenance all show up under their categories.
    let counts = event_counts(&json1).unwrap();
    let c = |cat: &str, name: &str| {
        counts
            .get(&(cat.to_string(), name.to_string()))
            .copied()
            .unwrap_or(0)
    };
    assert_eq!(c("batch", "exec") as u64, r1.batches(), "one exec span per batch");
    assert_eq!(c("weights", "reload") as u64, r1.reloads(), "one reload span per reload");
    assert_eq!(c("weights", "prewarm") as u64, r1.prewarms(), "one prewarm span per prewarm");
    assert!(c("batch", "batch_open") > 0, "fresh batches emit open instants");
    assert_eq!(c("fault", "crash"), 1, "the pinned crash fires once");
    assert_eq!(c("fault", "down"), 1, "one down window per crash");
    assert_eq!(c("fault", "recover"), 1, "the worker comes back");
    assert!(c("controller", "controller_tick") > 0, "adaptive controller ticks");
    assert!(c("residency", "load") > 0, "weight loads land on the residency lane");
    assert!(c("residency", "evict") > 0, "the crash evicts residency");
    assert!(c("plan", "computed") > 0, "fresh plan computations are recorded");

    // Metrics snapshot: fleet, per-network, per-worker, chaos, movement,
    // plan-cache, and trace self-accounting all registered.
    for key in [
        "serve.completed_total",
        "serve.workers",
        "net.mobilenetv1.batches_total",
        "worker.0.crashes_total",
        "chaos.crashes_total",
        "movement.fraction",
        "movement.reload.bytes_total",
        "plan_cache.misses_total",
        "trace.events_total",
    ] {
        assert!(
            text1.lines().any(|l| l.starts_with(&format!("{key} "))),
            "metric {key} missing from:\n{text1}"
        );
    }
    // Deterministic export order: sorted by name.
    let names: Vec<&str> = text1.lines().filter_map(|l| l.split(' ').next()).collect();
    let mut sorted = names.clone();
    sorted.sort_unstable();
    assert_eq!(names, sorted, "metrics text must be name-sorted");
}

#[test]
fn streaming_sink_writes_the_exact_buffered_bytes() {
    let nets = skewed_nets();
    let trace = skewed_trace(60);
    // Brownout plan so the synthetic fault lane gets a window span too.
    let cfg = SimServeConfig {
        faults: FaultPlan::parse("dramslow:0.5x@0.2s..0.8s").unwrap(),
        ..base_cfg()
    };

    let buffered = replay_obs(
        &engine(),
        &nets,
        &trace,
        cfg.clone(),
        Some(TraceSink::buffered()),
        false,
    )
    .unwrap();
    let bdone = buffered.trace.as_ref().unwrap();
    let json = bdone.json.as_ref().unwrap();
    assert_eq!(event_counts(json).unwrap().get(&("fault".into(), "dram_brownout".into())), Some(&1));
    // Lanes are named for the Perfetto UI: workers, controller, faults, plan.
    for lane in ["worker 0", "worker 2", "controller", "faults", "plan"] {
        assert!(json.contains(lane), "lane `{lane}` unnamed in:\n{json}");
    }

    let dir = std::env::temp_dir().join("pimflow_obs_trace_test");
    let path = dir.join("stream.trace.json");
    let streamed = replay_obs(
        &engine(),
        &nets,
        &trace,
        cfg,
        Some(TraceSink::streaming(&path).unwrap()),
        false,
    )
    .unwrap();
    let sdone = streamed.trace.as_ref().unwrap();
    assert_eq!(sdone.events, bdone.events);
    assert_eq!(sdone.high_water, 0, "streaming sinks buffer nothing");
    assert_eq!(sdone.path.as_deref(), Some(path.as_path()));
    let on_disk = std::fs::read_to_string(&path).unwrap();
    assert_eq!(&on_disk, json, "streaming and buffered sinks must emit identical bytes");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn movement_share_decreases_monotonically_along_the_batch_ladder() {
    // The acceptance curve: one trace, a growing max_batch ladder, the
    // DRAM (data-movement) share of fleet energy falling rung over rung —
    // batching amortizes both the per-batch weight streaming and the
    // reload rate, the paper's Fig. 7 argument lifted to the fleet.
    let eng = engine();
    let (nets, trace) = mixed_trace(
        &["mobilenetv1", "vgg11"],
        96,
        pimflow::coordinator::Arrival::Poisson(2000.0),
        11,
    )
    .unwrap();
    let base = SimServeConfig {
        slo_s: 1e6,
        max_batch: 8,
        max_wait_s: 0.001,
        workers: 2,
        ..SimServeConfig::default()
    };
    let rows = movement_sweep(&eng, &nets, &trace, &base, &[1, 2, 4, 8]).unwrap();
    assert_eq!(rows.len(), 4);
    for w in rows.windows(2) {
        assert!(
            w[1].movement_fraction <= w[0].movement_fraction,
            "movement share grew with batch: {} @ b={} -> {} @ b={}",
            w[0].movement_fraction,
            w[0].max_batch,
            w[1].movement_fraction,
            w[1].max_batch
        );
    }
    let first = &rows[0];
    let last = rows.last().unwrap();
    assert!(
        last.movement_fraction < first.movement_fraction,
        "the ladder must actually amortize: {} !< {}",
        last.movement_fraction,
        first.movement_fraction
    );
    assert!(last.movement_fraction > 0.0 && last.movement_fraction < 1.0);
    assert!(
        first.reloads >= last.reloads,
        "bigger batches cannot reload more often"
    );
    // Every rung attributes every executed batch and every reload.
    for r in &rows {
        let m = r.report.movement.as_ref().unwrap();
        assert_eq!(
            m.by_cause(pimflow::obs::MoveCause::Batch).events,
            r.report.batches()
        );
        assert_eq!(
            m.by_cause(pimflow::obs::MoveCause::Reload).events,
            r.report.reloads()
        );
    }
}
