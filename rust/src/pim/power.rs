//! Chip power budget: average/peak power draw at an operating point and a
//! TDP feasibility check — the constraint that ultimately bounds how much
//! duplication a compact chip can exploit (every duplicate copy fires its
//! subarrays in parallel).

use crate::cfg::chip::ChipConfig;
use crate::nn::Layer;

use super::chip::ChipModel;

/// Power draw summary for one layer executing at full rate.
#[derive(Debug, Clone, Copy)]
pub struct LayerPower {
    /// Average dynamic power while the layer streams, W.
    pub dynamic_w: f64,
    /// Peak instantaneous power (all subarrays × dup active), W.
    pub peak_w: f64,
}

/// Dynamic power of `layer` at duplication `dup`: every copy activates its
/// subarrays once per MVM round; more duplication = more parallel reads =
/// proportionally higher draw for proportionally less time.
pub fn layer_power(chip: &ChipModel, layer: &Layer, dup: u32) -> LayerPower {
    let dup = dup.max(1) as f64;
    let subarrays = chip.layer_subarrays(layer) as f64;
    // one MVM round: `subarrays` reads over t_mvm
    let e_round_j = subarrays * chip.cfg.e_mvm_pj() * 1e-12;
    let t_round_s = chip.cfg.t_mvm_ns() * 1e-9;
    let per_copy_w = e_round_j / t_round_s;
    LayerPower {
        dynamic_w: per_copy_w * dup,
        peak_w: per_copy_w * dup,
    }
}

/// Whole-chip power at an operating point: the streaming part's layers all
/// fire concurrently in the pipeline.
pub fn part_power_w(chip: &ChipModel, layers: &[(&Layer, u32)]) -> f64 {
    let dynamic: f64 = layers
        .iter()
        .map(|(l, d)| layer_power(chip, l, *d).dynamic_w)
        .sum();
    dynamic + chip.leak_w()
}

/// Default thermal budget for a mobile-class 41.5 mm² accelerator, W.
pub fn default_tdp_w(cfg: &ChipConfig) -> f64 {
    // ~0.15 W/mm² mobile budget.
    0.15 * super::area::chip_area_mm2(cfg)
}

/// Does the mapped part stay within the TDP?
pub fn within_tdp(chip: &ChipModel, layers: &[(&Layer, u32)]) -> bool {
    part_power_w(chip, layers) <= default_tdp_w(&chip.cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cfg::presets;
    use crate::ddm;
    use crate::nn::resnet;
    use crate::partition::partition;

    fn chip() -> ChipModel {
        ChipModel::new(presets::compact_rram_41mm2()).unwrap()
    }

    #[test]
    fn duplication_scales_power_linearly() {
        let c = chip();
        let l = Layer::conv("l", 16, 64, 64, 3, 1, 1);
        let p1 = layer_power(&c, &l, 1);
        let p4 = layer_power(&c, &l, 4);
        assert!((p4.dynamic_w / p1.dynamic_w - 4.0).abs() < 1e-9);
    }

    #[test]
    fn compact_chip_parts_fit_mobile_tdp() {
        // The paper's efficiency story requires sub-watt compute; every
        // DDM-mapped part must stay within the ~6 W mobile budget.
        let c = chip();
        let net = resnet::resnet34(100);
        let plan = partition(&net, &c).unwrap();
        let dd = ddm::run(&plan, &c);
        for (part, dups) in plan.parts.iter().zip(&dd.dup_per_part) {
            let layers: Vec<(&Layer, u32)> = part
                .units
                .iter()
                .zip(dups)
                .map(|(u, &d)| (&u.layer, d))
                .collect();
            let p = part_power_w(&c, &layers);
            assert!(
                within_tdp(&c, &layers),
                "part draws {p:.2} W > TDP {:.2} W",
                default_tdp_w(&c.cfg)
            );
        }
    }

    #[test]
    fn tdp_scales_with_area() {
        let small = presets::compact_rram_41mm2();
        let big = small.with_tiles(small.num_tiles * 3);
        assert!(default_tdp_w(&big) > default_tdp_w(&small));
    }

    #[test]
    fn power_includes_leakage_floor() {
        let c = chip();
        assert!(part_power_w(&c, &[]) >= c.leak_w());
    }
}
