//! 8-bit quantization spec shared between the simulator and the AOT
//! artifacts (paper: "weights and activations of NN are quantized to 8-bit"
//! following WAGE-style integer inference [22]).

/// Fixed quantization format of the deployed networks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QuantSpec {
    /// Weight bits (signed).
    pub weight_bits: u32,
    /// Activation bits (unsigned, post-ReLU).
    pub act_bits: u32,
    /// Accumulator bits (digital shift-add output).
    pub acc_bits: u32,
}

impl Default for QuantSpec {
    fn default() -> Self {
        QuantSpec {
            weight_bits: 8,
            act_bits: 8,
            acc_bits: 32,
        }
    }
}

impl QuantSpec {
    /// Bytes to store `n` weights.
    pub fn weight_bytes(&self, n: u64) -> u64 {
        (n * self.weight_bits as u64).div_ceil(8)
    }

    /// Bytes to store `n` activations.
    pub fn act_bytes(&self, n: u64) -> u64 {
        (n * self.act_bits as u64).div_ceil(8)
    }

    /// Worst-case accumulator magnitude for a K-row dot product: guards
    /// the digital datapath width.
    pub fn max_abs_acc(&self, k: u64) -> u64 {
        let max_act = (1u64 << self.act_bits) - 1;
        let max_w = 1u64 << (self.weight_bits - 1);
        k * max_act * max_w
    }

    /// True if `acc_bits` can hold any K-row dot product without overflow.
    pub fn acc_fits(&self, k: u64) -> bool {
        let max = self.max_abs_acc(k);
        max < (1u64 << (self.acc_bits - 1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_8_8_32() {
        let q = QuantSpec::default();
        assert_eq!((q.weight_bits, q.act_bits, q.acc_bits), (8, 8, 32));
    }

    #[test]
    fn byte_packing() {
        let q = QuantSpec::default();
        assert_eq!(q.weight_bytes(10), 10);
        let q4 = QuantSpec {
            weight_bits: 4,
            ..q
        };
        assert_eq!(q4.weight_bytes(10), 5);
    }

    #[test]
    fn acc_width_guard() {
        let q = QuantSpec::default();
        // 255*128*K < 2^31 requires K < 65793: all our layers are far below.
        assert!(q.acc_fits(4608)); // largest ResNet K = 3*3*512
        assert!(!q.acc_fits(70_000));
    }
}
