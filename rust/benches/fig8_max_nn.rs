//! Bench: regenerate Fig. 8 (max NN size exploration) through the shared
//! engine — the paper's ResNet axis and the zoo axis — and time one row
//! of each family.

use pimflow::bench_harness::Bench;
use pimflow::cfg::presets;
use pimflow::explore::{
    ddm_row, fig8_sweep, max_deployable, paper_networks, zoo_sweep, Design, Engine, Floor,
};
use pimflow::nn::zoo;

use pimflow::report::figures;

fn main() {
    let engine = Engine::compact(presets::lpddr5());

    let mut b = Bench::from_env();
    let net = zoo::by_name("resnet50", 100).unwrap();
    let vgg = zoo::by_name("vgg16", 100).unwrap();
    let mobile = zoo::by_name("mobilenetv1", 100).unwrap();
    b.case("fig8_row_resnet50", || {
        engine.run(Design::CompactDdm, &net, 64).unwrap()
    });
    b.case("fig8_row_vgg16", || {
        engine.run(Design::CompactDdm, &vgg, 64).unwrap()
    });
    b.case("fig8_row_mobilenetv1", || {
        engine.run(Design::CompactDdm, &mobile, 64).unwrap()
    });
    b.report();

    let pts = fig8_sweep(&engine, &paper_networks(), 256).unwrap();
    let (table, csv) = figures::fig8_table(&pts).unwrap();
    print!("{}", table.render());
    let _ = figures::write_csv(&csv, "fig8_max_nn.csv");

    // The zoo axis: same engine, same cache, three families on one table.
    let zoo_pts = zoo_sweep(&engine, 256).unwrap();
    let (zoo_table, zoo_csv) = figures::fig8_table(&zoo_pts).unwrap();
    print!("{}", zoo_table.render());
    let _ = figures::write_csv(&zoo_csv, "fig8_zoo.csv");

    // The paper's recommendation logic: pick a floor between the family
    // extremes and report the largest deployable network.
    let first = ddm_row(&pts, "resnet18").unwrap();
    let last = ddm_row(&pts, "resnet152").unwrap();
    let floor = Floor {
        min_fps: (first.throughput_fps + last.throughput_fps) / 2.0,
        min_tops_per_watt: 4.0,
    };
    match max_deployable(&zoo_pts, floor) {
        Some(best) => println!(
            "max deployable under floor (>{:.0} FPS, >4 TOPS/W): {} ({:.1}M)",
            floor.min_fps,
            best.network,
            best.weights as f64 / 1e6
        ),
        None => println!("no network meets the floor"),
    }
    assert!(
        last.throughput_fps < first.throughput_fps,
        "throughput must fall across the family"
    );
}
