//! Serving coordinator (L3 request path): request types, dynamic
//! [`batcher`], [`worker`] pool, and the [`server::Server`] façade.
//!
//! Request flow: `Server::submit` → queue → `gather` (max-batch /
//! max-wait policy) → smallest fitting AOT artifact variant → PJRT
//! execute → per-request reply channels. All Rust; Python was only used
//! at build time to author and lower the model.

pub mod batcher;
pub mod loadgen;
pub mod request;
pub mod server;
pub mod worker;

pub use batcher::BatchPolicy;
pub use request::{InferRequest, InferResponse, RequestId, IMAGE_ELEMENTS};
pub use loadgen::{run_load, Arrival, LoadReport};
pub use server::{Server, ServerConfig, StatsSnapshot};
