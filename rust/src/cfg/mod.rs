//! Configuration: a minimal TOML parser plus typed chip / DRAM / simulation
//! configs and calibrated presets.
//!
//! Config files carry three tables:
//!
//! ```toml
//! [chip]
//! name = "compact"
//! num_tiles = 13
//! # ... see ChipConfig
//!
//! [dram]
//! kind = "lpddr5"
//! # ... see DramConfig
//!
//! [sim]
//! network = "resnet34"
//! batch = 64
//! ```

pub mod chip;
pub mod dram;
pub mod presets;
pub mod sim;
pub mod toml;

pub use chip::{CellTech, ChipConfig};
pub use dram::{DramConfig, DramKind};
pub use sim::{PipelineCase, SimConfig};

use anyhow::Context;
use std::path::Path;

/// A fully parsed config file (all tables optional; presets fill gaps).
#[derive(Debug, Clone)]
pub struct Config {
    pub chip: ChipConfig,
    pub dram: DramConfig,
    pub sim: SimConfig,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            chip: presets::compact_rram_41mm2(),
            dram: presets::lpddr5(),
            sim: SimConfig::default(),
        }
    }
}

impl Config {
    /// Parse a TOML document; absent tables fall back to presets.
    pub fn from_str(text: &str) -> anyhow::Result<Self> {
        let doc = toml::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let mut cfg = Config::default();
        if let Some(chip) = doc.get("chip") {
            cfg.chip = ChipConfig::from_toml(chip).context("[chip]")?;
        }
        if let Some(dram) = doc.get("dram") {
            cfg.dram = DramConfig::from_toml(dram).context("[dram]")?;
        }
        if let Some(sim) = doc.get("sim") {
            cfg.sim = SimConfig::from_toml(sim).context("[sim]")?;
        }
        Ok(cfg)
    }

    pub fn from_file(path: &Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::from_str(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_config_uses_presets() {
        let c = Config::from_str("").unwrap();
        assert_eq!(c.chip.num_tiles, 205);
        assert_eq!(c.dram.kind, DramKind::Lpddr5);
    }

    #[test]
    fn partial_override() {
        let c = Config::from_str(
            r#"
            [sim]
            network = "resnet50"
            batch = 128
            "#,
        )
        .unwrap();
        assert_eq!(c.sim.network, "resnet50");
        assert_eq!(c.sim.batch, 128);
        assert_eq!(c.chip.num_tiles, 205); // preset untouched
    }

    #[test]
    fn bad_table_is_an_error() {
        assert!(Config::from_str("[sim]\nbatch = 0").is_err());
    }
}
