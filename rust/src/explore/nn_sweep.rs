//! NN-size exploration (Fig. 8): deploy each ResNet on the fixed compact
//! chip and find the largest network that still meets the performance
//! floor (paper: energy efficiency > 8 TOPS/W and throughput > 3000 FPS →
//! deploy NNs smaller than ResNet-101).

use crate::baselines::unlimited_chip;
use crate::cfg::dram::DramConfig;
use crate::cfg::presets;
use crate::nn::resnet;
use crate::sim::{System, SystemReport};

/// One Fig. 8 row: the three designs on one network.
#[derive(Debug, Clone)]
pub struct Fig8Point {
    pub network: String,
    pub weights: u64,
    pub no_ddm: SystemReport,
    pub ddm: SystemReport,
    pub unlimited: SystemReport,
}

/// Reference batch used for the exploration.
pub const EXPLORE_BATCH: u32 = 256;

/// Sweep the paper's ResNet family on the compact chip.
pub fn fig8_sweep(dram: &DramConfig, batch: u32) -> Vec<Fig8Point> {
    let compact = presets::compact_rram_41mm2();
    resnet::paper_family(100)
        .into_iter()
        .map(|net| {
            let unlim_cfg = unlimited_chip(&compact, &net);
            Fig8Point {
                weights: net.total_weights(),
                no_ddm: System::new(compact.clone(), dram.clone())
                    .with_ddm(false)
                    .run(&net, batch),
                ddm: System::new(compact.clone(), dram.clone()).run(&net, batch),
                unlimited: System::new(unlim_cfg, dram.clone()).run(&net, batch),
                network: net.name,
            }
        })
        .collect()
}

/// Performance floor for the deployment recommendation.
#[derive(Debug, Clone, Copy)]
pub struct Floor {
    pub min_tops_per_watt: f64,
    pub min_fps: f64,
}

/// The largest network (by weights) whose compact+DDM point meets `floor`.
pub fn max_deployable<'a>(points: &'a [Fig8Point], floor: Floor) -> Option<&'a Fig8Point> {
    points
        .iter()
        .filter(|p| {
            p.ddm.tops_per_watt > floor.min_tops_per_watt && p.ddm.throughput_fps > floor.min_fps
        })
        .max_by_key(|p| p.weights)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cfg::presets;

    fn sweep() -> Vec<Fig8Point> {
        fig8_sweep(&presets::lpddr5(), 64)
    }

    #[test]
    fn throughput_decreases_with_nn_size() {
        // Paper: "inference throughput decreases rapidly as the NN grows".
        // Partition/DDM luck can wobble a single step (R101→R152 gains a
        // few %), so assert the trend: no step regresses upward by >15%
        // and the family's endpoints differ by >2×.
        let pts = sweep();
        for w in pts.windows(2) {
            assert!(
                w[1].ddm.throughput_fps < w[0].ddm.throughput_fps * 1.15,
                "{} vs {}",
                w[0].network,
                w[1].network
            );
        }
        let first = pts.first().unwrap().ddm.throughput_fps;
        let last = pts.last().unwrap().ddm.throughput_fps;
        assert!(last < first / 2.0, "endpoints {first} vs {last}");
    }

    #[test]
    fn efficiency_stays_in_regime() {
        // Paper: energy efficiency fluctuates slightly but stays >8 TOPS/W.
        let pts = sweep();
        for p in &pts {
            assert!(
                p.ddm.tops_per_watt > 2.0,
                "{}: {} TOPS/W",
                p.network,
                p.ddm.tops_per_watt
            );
        }
        let effs: Vec<f64> = pts.iter().map(|p| p.ddm.tops_per_watt).collect();
        let min = effs.iter().copied().fold(f64::INFINITY, f64::min);
        let max = effs.iter().copied().fold(0.0, f64::max);
        assert!(max / min < 4.0, "efficiency swing too wide: {effs:?}");
    }

    #[test]
    fn max_deployable_respects_floor() {
        let pts = sweep();
        // A floor nothing meets:
        assert!(max_deployable(
            &pts,
            Floor {
                min_tops_per_watt: 1e9,
                min_fps: 1e12
            }
        )
        .is_none());
        // A floor everything meets returns the largest net:
        let all = max_deployable(
            &pts,
            Floor {
                min_tops_per_watt: 0.0,
                min_fps: 0.0,
            },
        )
        .unwrap();
        assert_eq!(all.network, "resnet152");
    }

    #[test]
    fn paper_style_floor_selects_mid_family() {
        // With a floor between the family's extremes the answer must be a
        // strict subset boundary (the paper lands between R50 and R101).
        let pts = sweep();
        let mid_fps =
            (pts.last().unwrap().ddm.throughput_fps + pts[0].ddm.throughput_fps) / 2.0;
        let pick = max_deployable(
            &pts,
            Floor {
                min_tops_per_watt: 0.0,
                min_fps: mid_fps,
            },
        )
        .unwrap();
        assert_ne!(pick.network, "resnet152");
    }
}
