//! Bench: regenerate Fig. 1 (area-unlimited chip area, SRAM vs RRAM) and
//! time the area model.

use pimflow::bench_harness::Bench;
use pimflow::report::figures;

fn main() {
    let mut b = Bench::from_env();
    b.case("fig1_table", figures::fig1_table);
    b.report();

    let (table, csv) = figures::fig1_table();
    print!("{}", table.render());
    let _ = figures::write_csv(&csv, "fig1_area.csv");
}
