//! Subarray model: one crossbar (rows × cols cells) plus its DAC row
//! drivers, column ADCs, and shift-add — the unit that executes one MVM.

use crate::cfg::chip::ChipConfig;

/// How many subarrays a `K × N` weight matrix occupies: `K` rows split into
/// row-chunks of `subarray_rows`, `N` outputs split into column chunks of
/// `weight_cols_per_subarray`.
pub fn subarrays_for(cfg: &ChipConfig, k: u32, n: u32) -> u64 {
    let row_chunks = k.div_ceil(cfg.subarray_rows) as u64;
    let col_chunks = n.div_ceil(cfg.weight_cols_per_subarray()) as u64;
    row_chunks * col_chunks
}

/// Latency of one full-precision MVM (all of a layer's subarrays fire in
/// parallel; activation bits stream serially), ns.
pub fn mvm_latency_ns(cfg: &ChipConfig) -> f64 {
    cfg.t_mvm_ns()
}

/// Energy of activating `count` subarrays for one MVM, pJ.
pub fn mvm_energy_pj(cfg: &ChipConfig, count: u64) -> f64 {
    count as f64 * cfg.e_mvm_pj()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cfg::presets;

    #[test]
    fn exact_fit_single_subarray() {
        let c = presets::compact_rram_41mm2(); // 128 rows, 32 weight cols
        assert_eq!(subarrays_for(&c, 128, 32), 1);
    }

    #[test]
    fn row_and_col_chunking() {
        let c = presets::compact_rram_41mm2();
        assert_eq!(subarrays_for(&c, 129, 32), 2); // one extra row chunk
        assert_eq!(subarrays_for(&c, 128, 33), 2); // one extra col chunk
        assert_eq!(subarrays_for(&c, 576, 64), 5 * 2); // resnet stage1 conv
    }

    #[test]
    fn tiny_layer_still_takes_one() {
        let c = presets::compact_rram_41mm2();
        assert_eq!(subarrays_for(&c, 27, 16), 1);
    }

    #[test]
    fn sram_needs_more_column_chunks() {
        let c = presets::compact_sram(); // 16 weight cols per subarray
        assert_eq!(subarrays_for(&c, 128, 32), 2);
    }
}
