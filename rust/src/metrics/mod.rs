//! Metric definitions shared by the simulator, baselines, and reports:
//! throughput (FPS), energy efficiency (TOPS/W), area efficiency
//! (GOPS/mm²), and the Fig. 7 energy breakdown.

use crate::nn::Network;
use crate::pim::EnergyLedger;

/// Throughput in frames (IFMs) per second.
pub fn fps(batch: u32, makespan_s: f64) -> f64 {
    batch as f64 / makespan_s
}

/// Energy efficiency in TOPS/W: total ops executed over total energy.
/// (ops/J == ops-per-second per watt.)
pub fn tops_per_watt(net: &Network, batch: u32, total_energy_j: f64) -> f64 {
    let ops = net.total_ops() as f64 * batch as f64;
    ops / total_energy_j / 1e12
}

/// Area efficiency in GOPS/mm² at the achieved throughput.
pub fn gops_per_mm2(net: &Network, throughput_fps: f64, area_mm2: f64) -> f64 {
    let ops_per_s = throughput_fps * net.total_ops() as f64;
    ops_per_s / area_mm2 / 1e9
}

/// Energy-per-inference in joules.
pub fn energy_per_ifm_j(batch: u32, total_energy_j: f64) -> f64 {
    total_energy_j / batch as f64
}

/// Fig. 7's quantity: on-chip (computation) share of total system energy.
pub fn compute_fraction(e: &EnergyLedger) -> f64 {
    e.compute_fraction()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::resnet;

    #[test]
    fn fps_definition() {
        assert!((fps(100, 0.5) - 200.0).abs() < 1e-9);
    }

    #[test]
    fn tops_per_watt_definition() {
        let net = resnet::resnet34(100);
        // 1 batch, energy such that eff = ops / E / 1e12
        let e = net.total_ops() as f64 / 1e12; // -> exactly 1 TOPS/W
        assert!((tops_per_watt(&net, 1, e) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn gops_per_mm2_definition() {
        let net = resnet::resnet34(100);
        let thr = 1000.0;
        let v = gops_per_mm2(&net, thr, 41.5);
        let expect = thr * net.total_ops() as f64 / 41.5 / 1e9;
        assert!((v - expect).abs() < 1e-9);
    }

    #[test]
    fn energy_per_ifm() {
        assert!((energy_per_ifm_j(10, 1.0) - 0.1).abs() < 1e-12);
    }
}
